"""Benchmarks mirroring the paper's evaluation (§6 + the CUDA tables)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------- registry
#
# ``benchmarks.run`` enumerates this table instead of hardcoding choices.
# ``kind=`` ties a bench to a solver kind from ``repro.core.kinds``; the
# harness asserts every registered kind has a tied bench, so adding a
# solver kind without a benchmark fails loudly instead of silently
# shipping unmeasured.
BENCHES: dict = {}
KIND_BENCHES: dict = {}  # solver kind name -> bench name


def bench(name, *, kind=None):
    def deco(fn):
        if name in BENCHES:
            raise ValueError(f"duplicate bench name {name!r}")
        BENCHES[name] = fn
        if kind is not None:
            KIND_BENCHES[kind] = name
        return fn
    return deco


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


@bench("maxflow", kind="maxflow")
def bench_maxflow(rows, repeats=2):
    """Paper §4: push-relabel on grid graphs (vision-scale sizes)."""
    from repro.core.maxflow.grid import GridProblem, maxflow_grid
    from repro.core.maxflow.ref import random_grid_problem
    rng = np.random.default_rng(0)
    for hw in (32, 64, 128, 256):
        cap, cs, ct = random_grid_problem(rng, hw, hw, max_cap=20,
                                          terminal_density=0.3)
        prob = GridProblem(jnp.asarray(cap), jnp.asarray(cs),
                           jnp.asarray(ct))
        res = maxflow_grid(prob)
        us = _time(maxflow_grid, prob, reps=repeats)
        rows.append((f"maxflow_grid_{hw}x{hw}", us, int(res.rounds),
                     f"flow={float(res.flow):.0f};"
                     f"heuristics={int(res.heuristics)};"
                     f"Mnode_rounds_per_s="
                     f"{hw*hw*int(res.rounds)/us:.1f}"))


@bench("adversarial")
def bench_adversarial(rows, repeats=2, sizes=None):
    """Workload-balanced backend vs the paper-faithful round on adversarial
    instance families (benchmarks/RESULTS_adversarial.md).

    Three generators from ``repro.core.maxflow.ref`` stress what
    ``backend="balanced"`` changes: ``long_path`` (stranded excess must
    travel home — the bidirectional relabel's win), ``checkerboard``
    (height-plateau oscillation — the stall trigger's win), and
    ``random_wide`` (ragged wide frontier — the active-tile schedule's
    win). Every solve is oracle-checked against scipy before timing, and
    the headline metric is the ROUNDS ratio (machine-independent; the CPU
    runner times the pallas path in interpret mode, so wall-clock favours
    xla here regardless of algorithmic merit — see RESULTS_adversarial.md).

    ``sizes`` defaults to (64, 256); the CI smoke step narrows it via
    ``BENCH_ADVERSARIAL_SIZES`` (comma-separated) to stay inside its time
    budget.
    """
    import os

    from repro.core.maxflow.grid import GridProblem, maxflow_grid
    from repro.core.maxflow.ref import (ADVERSARIAL_GENERATORS,
                                        maxflow_grid_ref)
    if sizes is None:
        env = os.environ.get("BENCH_ADVERSARIAL_SIZES", "")
        sizes = tuple(int(s) for s in env.split(",") if s) or (64, 256)
    rng = np.random.default_rng(0)
    for gname, gen in ADVERSARIAL_GENERATORS.items():
        for hw in sizes:
            cap, cs, ct = gen(rng, hw, hw)
            want = maxflow_grid_ref(cap, cs, ct)
            prob = GridProblem(jnp.asarray(cap), jnp.asarray(cs),
                               jnp.asarray(ct))
            meas = {}
            for be in ("xla", "balanced"):
                res = maxflow_grid(prob, backend=be, max_rounds=500_000)
                assert bool(res.converged), (gname, hw, be)
                assert float(res.flow) == float(want), (gname, hw, be)
                us = _time(maxflow_grid, prob, backend=be,
                           max_rounds=500_000, reps=repeats)
                meas[be] = (us, int(res.rounds))
                rows.append((f"adversarial_{gname}_{hw}x{hw}_{be}", us,
                             int(res.rounds),
                             f"flow={float(res.flow):.0f};"
                             f"heuristics={int(res.heuristics)}"))
            (us_x, r_x), (us_b, r_b) = meas["xla"], meas["balanced"]
            rows.append((f"adversarial_{gname}_{hw}x{hw}_gain", us_x - us_b,
                         None,
                         f"rounds_ratio={r_x / max(r_b, 1):.2f}x;"
                         f"speedup_vs_xla={us_x / us_b:.2f}x"))


@bench("batched")
def bench_batched(rows, repeats=2):
    """Batched multi-instance engine vs vmap-of-single (instances/sec).

    ``jax.vmap(maxflow_grid)`` is a strong baseline: vmap's while_loop
    batching rule also freezes converged lanes via selects, so its results
    (including per-instance round counters) are bit-identical to the
    explicit engine. What the comparison measures is the overhead of the
    FIRST-CLASS batch axis (hand-rolled liveness masks + selects, explicit
    (B, ...) layouts) relative to the vmap program transform; what the
    explicit engine buys instead of speed is the ragged pad-and-bucket
    front end, the public batched layout, and a place to hang compaction /
    batch-axis sharding (ROADMAP). B=1 measures the mask overhead alone.
    """
    from repro.core.batch import stack_grid_problems
    from repro.core.maxflow.grid import GridProblem, maxflow_grid_batch
    from repro.core.maxflow import grid as grid_mod
    from repro.core.maxflow.ref import random_grid_problem
    import jax
    rng = np.random.default_rng(0)
    hw = 64
    raw = [GridProblem(*map(jnp.asarray, random_grid_problem(
        rng, hw, hw, max_cap=20, terminal_density=0.3))) for _ in range(64)]

    def vmap_flow(prob):  # baseline: vmap the single-instance solver.
        # Returns the same outputs as the batched engine (flow AND cut) so
        # XLA cannot dead-code-eliminate the final min-cut BFS.
        def one(c, s, t):
            r = grid_mod.maxflow_grid(GridProblem(c, s, t))
            return r.flow, r.cut, r.converged
        return jax.vmap(one)(prob.cap_nbr, prob.cap_src, prob.cap_sink)

    vmap_flow = jax.jit(vmap_flow)
    for B in (1, 8, 64):
        prob = stack_grid_problems(raw[:B])
        res = maxflow_grid_batch(prob)
        us = _time(maxflow_grid_batch, prob, reps=repeats)
        us_v = _time(vmap_flow, prob, reps=repeats)
        rows.append((f"maxflow_batch_B{B}_{hw}x{hw}", us,
                     f"inst_per_s={B / us * 1e6:.1f};"
                     f"vmap_inst_per_s={B / us_v * 1e6:.1f};"
                     f"speedup_vs_vmap={us_v / us:.2f}x;"
                     f"mean_flow={float(jnp.mean(res.flow)):.0f}"))

    from repro.core.assignment.cost_scaling import solve_assignment
    n = 64
    ws = jnp.asarray(np.stack([
        np.random.default_rng(i).integers(0, 101, (n, n))
        for i in range(64)]), jnp.int32)

    def vmap_assign(w):  # full results, comparable outputs (no DCE skew)
        return jax.vmap(solve_assignment)(w)

    vmap_assign = jax.jit(vmap_assign)
    for B in (1, 8, 64):
        w = ws[:B]
        res = solve_assignment(w)
        us = _time(solve_assignment, w, reps=repeats)
        us_v = _time(vmap_assign, w, reps=repeats)
        rows.append((f"assignment_batch_B{B}_n{n}", us,
                     f"inst_per_s={B / us * 1e6:.1f};"
                     f"vmap_inst_per_s={B / us_v * 1e6:.1f};"
                     f"speedup_vs_vmap={us_v / us:.2f}x;"
                     f"mean_rounds={float(jnp.mean(res.rounds)):.0f}"))


@bench("sharded")
def bench_sharded(rows, repeats=2):
    """Batch-axis sharding over the device mesh: instances/sec vs devices.

    Run with emulated host devices to see >1 device on CPU:

        XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
            PYTHONPATH=src python -m benchmarks.run sharded

    Numbers land in benchmarks/RESULTS_sharded.md. dev=0 rows are the
    unsharded batched baseline (no shard_map in the dispatch).
    """
    import jax

    from repro.core.batch import stack_grid_problems
    from repro.core.maxflow.grid import GridProblem, maxflow_grid_batch
    from repro.core.maxflow.ref import random_grid_problem
    from repro.core.assignment.cost_scaling import solve_assignment
    from repro.launch.mesh import make_solver_mesh

    n_dev = len(jax.devices())
    counts = [c for c in (1, 2, 4, 8) if c <= n_dev]
    rng = np.random.default_rng(0)

    hw, B = 32, 32
    prob = stack_grid_problems(
        [GridProblem(*map(jnp.asarray, random_grid_problem(
            rng, hw, hw, max_cap=20, terminal_density=0.3)))
         for _ in range(B)])
    us0 = _time(maxflow_grid_batch, prob, reps=repeats)
    rows.append((f"maxflow_sharded_B{B}_{hw}x{hw}_dev0", us0,
                 f"inst_per_s={B / us0 * 1e6:.1f};unsharded_baseline"))
    for c in counts:
        mesh = make_solver_mesh(c)
        us = _time(maxflow_grid_batch, prob, mesh=mesh, reps=repeats)
        rows.append((f"maxflow_sharded_B{B}_{hw}x{hw}_dev{c}", us,
                     f"inst_per_s={B / us * 1e6:.1f};"
                     f"speedup_vs_unsharded={us0 / us:.2f}x"))

    n = 48
    ws = jnp.asarray(np.stack([
        np.random.default_rng(i).integers(0, 101, (n, n))
        for i in range(B)]), jnp.int32)
    us0 = _time(solve_assignment, ws, reps=repeats)
    rows.append((f"assignment_sharded_B{B}_n{n}_dev0", us0,
                 f"inst_per_s={B / us0 * 1e6:.1f};unsharded_baseline"))
    for c in counts:
        mesh = make_solver_mesh(c)
        us = _time(solve_assignment, ws, mesh=mesh, reps=repeats)
        rows.append((f"assignment_sharded_B{B}_n{n}_dev{c}", us,
                     f"inst_per_s={B / us * 1e6:.1f};"
                     f"speedup_vs_unsharded={us0 / us:.2f}x"))


@bench("compaction")
def bench_compaction(rows, repeats=2):
    """Early-exit compaction vs the masked baseline (instances/sec).

    A ragged-convergence batch — most instances converge within the first
    heuristic cycles, a few stragglers run long — is where the ROADMAP's
    compaction item pays: the masked path select-freezes converged
    instances but keeps computing full-batch cycles until the LAST
    straggler drains, while ``compact=True`` gathers the live instances
    into pow2-sized sub-batches so per-cycle FLOPs track the live count.
    Results are bit-identical (tests/test_compact.py); numbers land in
    benchmarks/RESULTS_compaction.md.
    """
    from repro.core.batch import stack_grid_problems
    from repro.core.maxflow.grid import GridProblem, maxflow_grid_batch
    from repro.core.maxflow.ref import random_grid_problem
    from repro.core.assignment.cost_scaling import solve_assignment

    rng = np.random.default_rng(0)
    hw, B, hard = 64, 32, 4
    probs = []
    for i in range(B):
        cap, cs, ct = random_grid_problem(rng, hw, hw, max_cap=20,
                                          terminal_density=0.3)
        if i >= hard:  # easy: almost no excess -> converge in early cycles
            cs = np.minimum(cs, 1.0)
        probs.append(GridProblem(*map(jnp.asarray, (cap, cs, ct))))
    prob = stack_grid_problems(probs)
    res = maxflow_grid_batch(prob)
    rag = (f"rounds_min={int(jnp.min(res.rounds))};"
           f"rounds_max={int(jnp.max(res.rounds))}")
    us_m = _time(maxflow_grid_batch, prob, reps=repeats)
    rows.append((f"maxflow_masked_B{B}_{hw}x{hw}", us_m,
                 f"inst_per_s={B / us_m * 1e6:.1f};{rag}"))
    us_c = _time(maxflow_grid_batch, prob, compact=True, reps=repeats)
    rows.append((f"maxflow_compact_B{B}_{hw}x{hw}", us_c,
                 f"inst_per_s={B / us_c * 1e6:.1f};"
                 f"speedup_vs_masked={us_m / us_c:.2f}x"))

    n = 64
    ws = np.stack([np.random.default_rng(i).integers(0, 101, (n, n))
                   for i in range(B)])
    ws[hard:] //= 25     # easy: small max|c| -> short eps schedules
    w = jnp.asarray(ws, jnp.int32)
    res = solve_assignment(w)
    rag = (f"rounds_min={int(jnp.min(res.rounds))};"
           f"rounds_max={int(jnp.max(res.rounds))}")
    us_m = _time(solve_assignment, w, reps=repeats)
    rows.append((f"assignment_masked_B{B}_n{n}", us_m,
                 f"inst_per_s={B / us_m * 1e6:.1f};{rag}"))
    us_c = _time(solve_assignment, w, compact=True, reps=repeats)
    rows.append((f"assignment_compact_B{B}_n{n}", us_c,
                 f"inst_per_s={B / us_c * 1e6:.1f};"
                 f"speedup_vs_masked={us_m / us_c:.2f}x"))


@bench("serving")
def bench_serving(rows, repeats=2):
    """Blocking-flush vs async-pipelined serving (throughput + latency).

    One recorded request stream — ragged-convergence grid cuts, the
    serving profile compaction pays on — is served three ways:

      * ``serving_blocking_flush`` — the PR-2 path: submit a chunk, call
        ``SolverEngine.flush()``, repeat. Host padding of chunk k+1 waits
        for the device solve of chunk k.
      * ``serving_async_masked`` — ``AsyncSolverEngine`` with the masked
        driver forced: size-triggered background flushes, host
        pad-and-bucket of batch k+1 overlapped with the device solve of
        batch k (double-buffered lanes).
      * ``serving_async_adaptive`` — adaptive dispatch on top: the
        convergence-spread EWMA flips ragged buckets to the compacted
        driver (dispatch counts in the derived column prove it).

    Derived columns report instances/sec and the async paths' p50/p99
    ticket latency (submit -> future resolution). Numbers land in
    benchmarks/RESULTS_serving.md (``python -m benchmarks.run serving``).
    """
    from repro.core.maxflow.grid import GridProblem
    from repro.core.maxflow.ref import random_grid_problem
    from repro.serve.engine import SolverEngine
    from repro.serve.scheduler import AsyncSolverEngine

    rng = np.random.default_rng(0)
    hw, B, chunk = 64, 32, 8
    probs = []
    for i in range(B):
        cap, cs, ct = random_grid_problem(rng, hw, hw, max_cap=20,
                                          terminal_density=0.3)
        if i % 4:   # 3 of 4 easy -> ragged convergence within every chunk
            cs = np.minimum(cs, 1.0)
        probs.append(GridProblem(*map(jnp.asarray, (cap, cs, ct))))

    def blocking():
        eng = SolverEngine(bucket="max")
        n = 0
        for lo in range(0, B, chunk):
            for p in probs[lo:lo + chunk]:
                eng.submit("maxflow", p)
            n += len(eng.flush())
        assert n == B

    def asynchronous(dispatch):
        metrics = None
        with AsyncSolverEngine(max_batch=chunk, max_delay_ms=10_000.0,
                               dispatch=dispatch, spread_threshold=0.15,
                               min_compact_batch=4) as eng:
            futs = [eng.submit("maxflow", p) for p in probs]
            for f in futs:
                f.result(timeout=600)
            metrics = eng.metrics
        return metrics

    blocking()                        # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        blocking()
    us_b = (time.perf_counter() - t0) / repeats * 1e6
    rows.append(("serving_blocking_flush", us_b,
                 f"inst_per_s={B / us_b * 1e6:.1f};chunks={B // chunk}"))

    for dispatch in ("masked", "adaptive"):
        asynchronous(dispatch)        # compile + warm the EWMA path
        t0 = time.perf_counter()
        for _ in range(repeats):
            m = asynchronous(dispatch)
        us_a = (time.perf_counter() - t0) / repeats * 1e6
        snap = m.snapshot()
        lat = snap["latency_ms"]
        extra = ""
        if dispatch == "adaptive":
            d = snap["dispatches"]
            extra = (f";masked_dispatches={d.get('maxflow:masked', 0)}"
                     f";compacted_dispatches="
                     f"{d.get('maxflow:compacted', 0)}")
        rows.append((f"serving_async_{dispatch}", us_a,
                     f"inst_per_s={B / us_a * 1e6:.1f};"
                     f"speedup_vs_blocking={us_b / us_a:.2f}x;"
                     f"p50_ms={lat['p50']:.1f};p99_ms={lat['p99']:.1f}"
                     + extra))


@bench("refill")
def bench_refill(rows, repeats=2):
    """Continuous batching vs closed batches on a ragged Poisson stream.

    The same recorded request stream — ragged-convergence grid cuts with
    Poisson inter-arrival gaps — is served twice through the async
    scheduler with the compacted driver forced:

      * ``refill_stream_closed`` — refill off: each flushed chunk drains
        as a closed compacted batch, so slots vacated by early-converging
        instances idle until the chunk's straggler finishes.
      * ``refill_stream_refill`` — ``refill=True``: freed slots are
        re-seeded from the pending queue at cycle boundaries
        (``repro.core.refill``), so the batch stays near capacity while
        requests keep arriving.

    The headline derived column is ``slot_occupancy``: mean live
    instances per compacted cycle over capacity (the closed path's
    ``compact_live_mean / max_batch``; the refill path's
    ``refill.utilization`` — the same per-cycle measure recorded by the
    session trace).  Steady-state occupancy must be strictly higher with
    refill; throughput and the admitted/session counts ride along.
    Numbers land in benchmarks/RESULTS_refill.md
    (``python -m benchmarks.run refill``).
    """
    from repro.core.maxflow.grid import GridProblem
    from repro.core.maxflow.ref import random_grid_problem
    from repro.serve.scheduler import AsyncSolverEngine

    rng = np.random.default_rng(0)
    hw, B, cap = 64, 48, 8
    probs = []
    for i in range(B):
        capn, cs, ct = random_grid_problem(rng, hw, hw, max_cap=20,
                                           terminal_density=0.3)
        if i % 4:   # 3 of 4 easy -> slots free early within every batch
            cs = np.minimum(cs, 1.0)
        probs.append(GridProblem(*map(jnp.asarray, (capn, cs, ct))))
    # one fixed Poisson arrival schedule, replayed identically both ways
    gaps = rng.exponential(0.002, B)

    def serve(refill):
        with AsyncSolverEngine(max_batch=cap, max_delay_ms=20.0,
                               dispatch="compacted", refill=refill,
                               n_lanes=2) as eng:
            futs = []
            for p, gap in zip(probs, gaps):
                time.sleep(gap)
                futs.append(eng.submit("maxflow", p))
            for f in futs:
                f.result(timeout=600)
            return eng.metrics.snapshot()

    results = {}
    for name, refill in (("closed", False), ("refill", True)):
        serve(refill)                 # compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            snap = serve(refill)
        us = (time.perf_counter() - t0) / repeats * 1e6
        if refill:
            occ = snap["refill"]["utilization"]
            extra = (f";admitted="
                     f"{sum(snap['refill']['admitted'].values())}"
                     f";sessions="
                     f"{sum(snap['refill']['sessions'].values())}")
        else:
            occ = snap["compact_live_mean"] / cap
            extra = ""
        results[name] = (us, occ)
        rows.append((f"refill_stream_{name}", us,
                     f"inst_per_s={B / us * 1e6:.1f};"
                     f"slot_occupancy={occ:.3f}" + extra))
    us_c, occ_c = results["closed"]
    us_r, occ_r = results["refill"]
    rows.append(("refill_stream_gain", us_c - us_r,
                 f"occupancy_gain={occ_r / occ_c:.2f}x;"
                 f"speedup_vs_closed={us_c / us_r:.2f}x"))


@bench("assignment", kind="assignment")
def bench_assignment(rows, repeats=2):
    """Paper §6: n<=30, costs<=100, ~1/20 s on a GTX 560 Ti."""
    from repro.core.assignment.cost_scaling import solve_assignment
    rng = np.random.default_rng(0)
    for n in (10, 30, 64, 128, 256):
        w = jnp.asarray(rng.integers(0, 101, (n, n)), jnp.int32)
        for method in ("pushrelabel", "auction"):
            res = solve_assignment(w, method=method)
            us = _time(solve_assignment, w, method=method, reps=repeats)
            note = ""
            if n == 30:
                note = f";paper_50000us_speedup={50_000/us:.1f}x"
            rows.append((f"assignment_{method}_n{n}", us, int(res.rounds),
                         f"ops={int(res.pushes)+int(res.relabels)}" + note))


@bench("matching", kind="matching")
def bench_matching(rows, repeats=2):
    """Bipartite maximum-cardinality matching (BFS augmenting rounds).

    Single-instance sizes vs the host Hopcroft-Karp oracle (the device
    path must match its cardinality exactly — asserted, not just timed),
    then the batched masked/compacted drivers, then the Pallas frontier
    backend end-to-end (interpret on CPU: correctness-scale timing)."""
    from repro.core.matching import match_bipartite, match_bipartite_batch
    from repro.core.matching.ref import hopcroft_karp, random_bipartite
    rng = np.random.default_rng(0)
    for n in (64, 128, 256):
        adj = jnp.asarray(random_bipartite(rng, n, n, p=4.0 / n))
        res = match_bipartite(adj)
        us = _time(match_bipartite, adj, reps=repeats)
        t0 = time.perf_counter()
        hk_card = hopcroft_karp(np.asarray(adj))[2]
        hk_us = (time.perf_counter() - t0) * 1e6
        assert int(res.cardinality) == int(hk_card)
        rows.append((f"matching_{n}x{n}", us, int(res.rounds),
                     f"card={int(res.cardinality)};hk_host_us={hk_us:.0f}"))
    B, n = 32, 64
    adjs = jnp.asarray(np.stack(
        [random_bipartite(rng, n, n, p=6.0 / n) for _ in range(B)]))
    res = match_bipartite_batch(adjs)
    us_m = _time(match_bipartite_batch, adjs, reps=repeats)
    rows.append((f"matching_masked_B{B}_n{n}", us_m,
                 f"inst_per_s={B / us_m * 1e6:.1f};"
                 f"rounds_min={int(jnp.min(res.rounds))};"
                 f"rounds_max={int(jnp.max(res.rounds))}"))
    us_c = _time(match_bipartite_batch, adjs, compact=True, reps=repeats)
    rows.append((f"matching_compact_B{B}_n{n}", us_c,
                 f"inst_per_s={B / us_c * 1e6:.1f};"
                 f"speedup_vs_masked={us_m / us_c:.2f}x"))
    adj32 = jnp.asarray(random_bipartite(rng, 32, 32, p=0.15))
    us_x = _time(match_bipartite, adj32, reps=repeats)
    us_p = _time(match_bipartite, adj32, backend="pallas", reps=repeats)
    rows.append(("matching_pallas_interp_32x32", us_p,
                 f"xla_us={us_x:.0f};interpret-mode frontier kernel"))


@bench("warmstart")
def bench_warmstart(rows, repeats=2):
    """Incremental re-solve: cold vs warm-started maxflow on an edit chain.

    A batch of grid instances is solved once, then mutated ``steps`` times
    (a few terminal-capacity edits per step — the docs/warmstart.md
    streaming pattern).  Each step is re-solved two ways on the SAME
    mutated problems:

      * ``warmstart_cold`` — from scratch through ``solve_batch``;
      * ``warmstart_warm`` — through ``solve_warm`` seeded with the
        previous step's solution (``WarmStart(sol, base_problem=prev)``).

    Warm and cold flows must bit-match (asserted — this bench doubles as
    an end-to-end equivalence check).  The headline numbers are the total
    push-relabel rounds down each chain: warm must spend strictly fewer.
    Numbers land in benchmarks/RESULTS_warmstart.md
    (``python -m benchmarks.run warmstart``).
    """
    from repro.core.batch import solve_batch
    from repro.core.kinds import get_kind
    from repro.core.maxflow.grid import GridProblem
    from repro.core.maxflow.ref import random_grid_problem
    from repro.core.warm import WarmStart, solve_warm

    rng = np.random.default_rng(0)
    kind = get_kind("maxflow")
    B, hw, steps = 4, 32, 3
    bases = []
    for _ in range(B):
        cap, cs, ct = random_grid_problem(rng, hw, hw, max_cap=20,
                                          terminal_density=0.3)
        bases.append(GridProblem(*map(jnp.asarray, (cap, cs, ct))))

    def mutate(p):
        # sparse terminal edits: the incremental-serving workload shape
        cs = np.asarray(p.cap_src).copy()
        ct = np.asarray(p.cap_sink).copy()
        for arr in (cs, ct):
            mask = rng.random(arr.shape) < 0.01
            arr[mask] = np.maximum(
                arr[mask] + rng.integers(-3, 4, int(mask.sum())), 0)
        return GridProblem(p.cap_nbr, *map(jnp.asarray, (cs, ct)))

    chains = [[p := b] + [p := mutate(p) for _ in range(steps)]
              for b in bases]

    def run_cold():
        rounds = 0
        res = None
        for s in range(1, steps + 1):
            res = solve_batch("maxflow", [c[s] for c in chains])
            rounds += sum(int(r.rounds) for r in res)
        return res, rounds

    base_res = solve_batch("maxflow", [c[0] for c in chains])

    def run_warm():
        # the base solve is shared state both paths already hold; only the
        # `steps` re-solves are timed, for warm and cold alike
        prev = base_res
        rounds = 0
        res = None
        for s in range(1, steps + 1):
            warm = {i: WarmStart(kind.solution_of(prev[i]),
                                 base_problem=chains[i][s - 1])
                    for i in range(B)}
            res = solve_warm("maxflow", [c[s] for c in chains], warm)
            rounds += sum(int(r.rounds) for r in res)
            prev = res
        return res, rounds

    (cold_res, cold_rounds), _ = run_cold(), run_warm()  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        cold_res, cold_rounds = run_cold()
    us_c = (time.perf_counter() - t0) / repeats * 1e6
    t0 = time.perf_counter()
    for _ in range(repeats):
        warm_res, warm_rounds = run_warm()
    us_w = (time.perf_counter() - t0) / repeats * 1e6

    for a, b in zip(cold_res, warm_res):
        assert float(a.flow) == float(b.flow), "warm != cold optimum"
    assert warm_rounds < cold_rounds, (warm_rounds, cold_rounds)
    rows.append(("warmstart_cold", us_c, cold_rounds,
                 f"B={B};hw={hw};steps={steps};"
                 f"flow_sum={sum(float(r.flow) for r in cold_res):.0f}"))
    rows.append(("warmstart_warm", us_w, warm_rounds,
                 f"rounds_saved={cold_rounds - warm_rounds}"))
    rows.append(("warmstart_gain", us_c - us_w,
                 f"rounds_ratio={cold_rounds / max(warm_rounds, 1):.2f}x;"
                 f"wall_speedup={us_c / us_w:.2f}x"))


@bench("refine_ops")
def bench_refine_ops(rows, repeats=2):
    """Operation-count scaling (the paper analyzes O(n^2 m) op bounds)."""
    from repro.core.assignment.cost_scaling import solve_assignment
    rng = np.random.default_rng(1)
    prev = None
    for n in (16, 32, 64, 128):
        w = jnp.asarray(rng.integers(0, 101, (n, n)), jnp.int32)
        res = solve_assignment(w, method="pushrelabel")
        ops = int(res.pushes) + int(res.relabels)
        growth = f";growth={ops/prev:.2f}x" if prev else ""
        prev = ops
        rows.append((f"refine_ops_n{n}", float(ops),
                     f"bound_n2m={n**2 * n * n}" + growth))


@bench("routing")
def bench_routing(rows, repeats=2):
    """Flow router vs top-k: drops, balance, overhead (MoE integration)."""
    from repro.core.routing import auction_route, topk_route
    rng = np.random.default_rng(0)
    T, E, k = 4096, 16, 2
    cap = int(T * k / E * 1.25)
    s = jnp.asarray(rng.normal(size=(T, E)).astype(np.float32))
    s = s.at[:, 0].add(2.0)  # hot expert
    for name, fn in (("topk", topk_route), ("flow", auction_route)):
        r = fn(s, k, cap)
        us = _time(fn, s, k, cap, reps=repeats)
        d = np.asarray(r.dispatch)
        load = d.sum(0)
        rows.append((f"route_{name}_T{T}_E{E}", us,
                     f"dropped={T*k - int(d.sum())};"
                     f"load_cv={load.std()/load.mean():.3f}"))


@bench("kernels")
def bench_kernels(rows, repeats=2):
    """Bidding kernel tile sweep (interpret on CPU: correctness-scale)."""
    from repro.kernels.bidding.kernel import bidding
    from repro.kernels.bidding.ref import bidding_ref
    rng = np.random.default_rng(0)
    n = 512
    c = jnp.asarray(rng.integers(-1000, 1000, (n, n)), jnp.int32)
    p = jnp.asarray(rng.integers(-500, 500, (n,)), jnp.int32)
    m = jnp.asarray(rng.random((n, n)) < 0.3)
    us_ref = _time(bidding_ref, c, p, m, reps=repeats)
    rows.append((f"bidding_ref_xla_n{n}", us_ref, "oracle"))
    for br, bc in ((128, 128), (256, 256), (256, 512)):
        vmem_kib = (br * bc * 5 + bc * 4 + br * 12) / 1024
        us = _time(bidding, c, p, m, block_rows=br, block_cols=bc,
                   interpret=True, reps=repeats)
        rows.append((f"bidding_kernel_{br}x{bc}_interp", us,
                     f"vmem_per_step_KiB={vmem_kib:.0f}"))


@bench("flash")
def bench_flash_kernel(rows, repeats=2):
    """Flash-attention Pallas kernel vs jnp flash path (interpret on CPU)."""
    from repro.kernels.flash_attention.kernel import flash_attention_fwd
    from repro.kernels.flash_attention.ref import flash_attention_ref
    import numpy as np
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    B, S, H, KV, dh = 1, 512, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, dh)).astype(np.float32))
    us_ref = _time(flash_attention_ref, q, k, v, reps=repeats)
    rows.append((f"flash_ref_xla_S{S}", us_ref, "dense oracle"))
    for bq, bk in ((128, 128), (256, 512)):
        vmem = (bq * dh + 2 * bk * dh + bq * bk + bq * (dh + 2)) * 4 / 1024
        us = _time(flash_attention_fwd, q, k, v, block_q=bq, block_k=bk,
                   interpret=True, reps=repeats)
        rows.append((f"flash_kernel_{bq}x{bk}_interp", us,
                     f"vmem_per_step_KiB={vmem:.0f}"))
