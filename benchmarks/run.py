"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,rounds,derived`` CSV (plus a trailing roofline
pointer: the dry-run roofline table lives in EXPERIMENTS.md and
results/dryrun_*.json). ``rounds`` is the solver's per-instance round
count — the machine-independent cost measure (wall-clock on the CPU CI
runner says little about TPU behaviour; round counts transfer). Benches
append either ``(name, us, rounds, derived)`` or the legacy 3-tuple
``(name, us, derived)`` (rounds column left empty).

Usage::

    python -m benchmarks.run [bench] [--repeats N] [--csv PATH]

The bench table is not hardcoded here: ``benchmarks.bench_flow`` registers
each benchmark with the ``@bench(name, kind=...)`` decorator and this
harness enumerates that registry. Benches tied to a solver kind are
cross-checked against ``repro.core.kinds.registered_kinds()`` — registering
a new solver kind without a benchmark makes every ``benchmarks.run``
invocation fail loudly instead of silently shipping the kind unmeasured.

Unknown bench names are rejected with the list of available benches
(previously they silently printed an empty CSV). ``--csv PATH`` writes the
same CSV to a file so callers (CI's artifact step) don't have to depend on
shell redirection or the current working directory.
"""
from __future__ import annotations

import argparse
import pathlib

from benchmarks.bench_flow import BENCHES, KIND_BENCHES


def _check_kind_coverage() -> None:
    """Every registered solver kind must have a bench tied to it."""
    from repro.core.kinds import registered_kinds
    missing = [k for k in registered_kinds() if k not in KIND_BENCHES]
    if missing:
        raise SystemExit(
            f"solver kinds without a benchmark: {', '.join(missing)} — "
            f"tie one in with @bench(name, kind=...) in "
            f"benchmarks/bench_flow.py")


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(
        prog="python -m benchmarks.run",
        description="Run one benchmark (or all) and print CSV to stdout.")
    parser.add_argument(
        "bench", nargs="?", choices=sorted(BENCHES), metavar="bench",
        help=f"which benchmark to run (default: all). "
             f"Available: {', '.join(sorted(BENCHES))}")
    parser.add_argument(
        "--repeats", type=int, default=2,
        help="timed repetitions per measurement after the compile call "
             "(default: %(default)s)")
    parser.add_argument(
        "--csv", type=pathlib.Path, default=None, metavar="PATH",
        help="also write the CSV to PATH (parent dirs created; output is "
             "still printed to stdout)")
    args = parser.parse_args(argv)
    _check_kind_coverage()

    rows: list[tuple] = []
    for name, fn in BENCHES.items():
        if args.bench and args.bench != name:
            continue
        fn(rows, repeats=args.repeats)
    lines = ["name,us_per_call,rounds,derived"]
    for row in rows:
        if len(row) == 4:
            name, us, rounds, derived = row
            r = "" if rounds is None else str(int(rounds))
        else:
            name, us, derived = row
            r = ""
        lines.append(f"{name},{us:.1f},{r},{derived}")
    print("\n".join(lines))
    if args.csv is not None:
        args.csv.parent.mkdir(parents=True, exist_ok=True)
        args.csv.write_text("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
