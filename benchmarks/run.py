"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,rounds,wall_s,derived`` CSV (plus a trailing
roofline pointer: the dry-run roofline table lives in EXPERIMENTS.md and
results/dryrun_*.json). ``rounds`` is the solver's per-instance round
count — the machine-independent cost measure (wall-clock on the CPU CI
runner says little about TPU behaviour; round counts transfer). Benches
append either ``(name, us, rounds, derived)`` or the legacy 3-tuple
``(name, us, derived)`` (rounds column left empty); ``wall_s`` is filled
in by the harness — wall-clock seconds from the previous row (or the
bench function's start) to this row's append, so the column sums to the
total harness runtime and exposes which measurement (compile + timing,
not just the timed calls) actually dominates a CI run.

Usage::

    python -m benchmarks.run [bench] [--repeats N] [--csv PATH]
                             [--trace PATH]

``--trace PATH`` installs an ambient ``repro.obs.Tracer`` around the
whole run and saves it as Chrome-trace JSON (open in Perfetto /
``chrome://tracing``). Engines the benches construct capture the ambient
tracer, so the serving benches emit full per-request lifecycle spans —
CI uploads the serving bench's trace as an artifact.

The bench table is not hardcoded here: ``benchmarks.bench_flow`` registers
each benchmark with the ``@bench(name, kind=...)`` decorator and this
harness enumerates that registry. Benches tied to a solver kind are
cross-checked against ``repro.core.kinds.registered_kinds()`` — registering
a new solver kind without a benchmark makes every ``benchmarks.run``
invocation fail loudly instead of silently shipping the kind unmeasured.

Unknown bench names are rejected with the list of available benches
(previously they silently printed an empty CSV). ``--csv PATH`` writes the
same CSV to a file so callers (CI's artifact step) don't have to depend on
shell redirection or the current working directory.
"""
from __future__ import annotations

import argparse
import contextlib
import pathlib
import time

from benchmarks.bench_flow import BENCHES, KIND_BENCHES


class _TimedRows(list):
    """Row sink that stamps wall-clock time at every ``append``.

    Benches are unaware of the ``wall_s`` column: they keep appending
    3/4-tuples and the harness derives per-row wall seconds from the
    append timestamps (delta from the previous append, or from ``mark()``
    at the start of the bench function for its first row).
    """

    def __init__(self):
        super().__init__()
        self.stamps: list[float] = []
        self._prev = time.monotonic()

    def mark(self) -> None:
        self._prev = time.monotonic()

    def append(self, row) -> None:
        now = time.monotonic()
        self.stamps.append(now - self._prev)
        self._prev = now
        super().append(row)


def _check_kind_coverage() -> None:
    """Every registered solver kind must have a bench tied to it."""
    from repro.core.kinds import registered_kinds
    missing = [k for k in registered_kinds() if k not in KIND_BENCHES]
    if missing:
        raise SystemExit(
            f"solver kinds without a benchmark: {', '.join(missing)} — "
            f"tie one in with @bench(name, kind=...) in "
            f"benchmarks/bench_flow.py")


def _check_row(row) -> None:
    """A bench row must lower cleanly into the CSV schema — fail FAST,
    naming the offending bench and row, instead of emitting a ragged line
    that downstream artifact parsing half-reads.

    Accepted shapes: ``(name, us, rounds, derived)`` or the legacy
    ``(name, us, derived)``; ``name`` a non-empty string without commas
    or newlines (it is a CSV cell), ``us`` a finite number, ``rounds`` an
    integer-valued number or ``None``.
    """
    def die(why: str):
        raise SystemExit(f"malformed bench row {row!r}: {why} — every row "
                         f"must match name,us_per_call,rounds,wall_s,derived")
    if not isinstance(row, tuple) or len(row) not in (3, 4):
        die("expected a (name, us, rounds, derived) or (name, us, derived) "
            "tuple")
    name, us = row[0], row[1]
    rounds = row[2] if len(row) == 4 else None
    if not isinstance(name, str) or not name or "," in name or "\n" in name:
        die("name must be a non-empty string without commas/newlines")
    try:
        us = float(us)
    except (TypeError, ValueError):
        die(f"us_per_call {us!r} is not a number")
    if us != us or us in (float("inf"), float("-inf")):
        die(f"us_per_call {us!r} is not finite")
    if rounds is not None:
        try:
            int(rounds)
        except (TypeError, ValueError):
            die(f"rounds {rounds!r} is not an integer count")


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(
        prog="python -m benchmarks.run",
        description="Run one benchmark (or all) and print CSV to stdout.")
    parser.add_argument(
        "bench", nargs="?", choices=sorted(BENCHES), metavar="bench",
        help=f"which benchmark to run (default: all). "
             f"Available: {', '.join(sorted(BENCHES))}")
    parser.add_argument(
        "--repeats", type=int, default=2,
        help="timed repetitions per measurement after the compile call "
             "(default: %(default)s)")
    parser.add_argument(
        "--csv", type=pathlib.Path, default=None, metavar="PATH",
        help="also write the CSV to PATH (parent dirs created; output is "
             "still printed to stdout)")
    parser.add_argument(
        "--trace", type=pathlib.Path, default=None, metavar="PATH",
        help="record a repro.obs trace of the whole run and save it as "
             "Chrome-trace JSON at PATH (open in Perfetto)")
    args = parser.parse_args(argv)
    _check_kind_coverage()

    tracer = None
    trace_ctx: contextlib.AbstractContextManager = contextlib.nullcontext()
    if args.trace is not None:
        from repro.obs.trace import Tracer, use_tracer
        tracer = Tracer()
        trace_ctx = use_tracer(tracer)

    rows = _TimedRows()
    with trace_ctx:
        for name, fn in BENCHES.items():
            if args.bench and args.bench != name:
                continue
            rows.mark()
            if tracer is not None:
                t0 = time.monotonic()
                fn(rows, repeats=args.repeats)
                tracer.record("bench", t0, time.monotonic(), bench=name)
            else:
                fn(rows, repeats=args.repeats)
    lines = ["name,us_per_call,rounds,wall_s,derived"]
    for row, wall in zip(rows, rows.stamps):
        _check_row(row)
        if len(row) == 4:
            name, us, rounds, derived = row
            r = "" if rounds is None else str(int(rounds))
        else:
            name, us, derived = row
            r = ""
        lines.append(f"{name},{us:.1f},{r},{wall:.3f},{derived}")
    print("\n".join(lines))
    if args.csv is not None:
        args.csv.parent.mkdir(parents=True, exist_ok=True)
        args.csv.write_text("\n".join(lines) + "\n")
    if tracer is not None:
        args.trace.parent.mkdir(parents=True, exist_ok=True)
        tracer.save(args.trace)


if __name__ == "__main__":
    main()
