"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus a trailing roofline pointer:
the dry-run roofline table lives in EXPERIMENTS.md and
results/dryrun_*.json).
"""
from __future__ import annotations

import sys

from benchmarks.bench_flow import (bench_assignment, bench_batched,
                                   bench_flash_kernel, bench_kernels,
                                   bench_maxflow, bench_refine_ops,
                                   bench_routing, bench_sharded)


def main() -> None:
    rows: list[tuple] = []
    only = sys.argv[1] if len(sys.argv) > 1 else None
    benches = {
        "maxflow": bench_maxflow,
        "batched": bench_batched,
        "sharded": bench_sharded,
        "assignment": bench_assignment,
        "refine_ops": bench_refine_ops,
        "routing": bench_routing,
        "kernels": bench_kernels,
        "flash": bench_flash_kernel,
    }
    for name, fn in benches.items():
        if only and only != name:
            continue
        fn(rows)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
