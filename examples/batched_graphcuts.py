"""Batched graph-cut segmentation — many images, ONE solver dispatch.

The serving-shaped version of examples/graphcut_segmentation.py: a mini
"request queue" of synthetic frames (ragged sizes included) is segmented by
the batched multi-instance engine of ``repro.core.batch``. Ragged frames are
zero-capacity padded to a bucket shape (value-preserving — padded pixels are
inert), every bucket is one ``maxflow_grid_batch`` dispatch, and per-instance
convergence masks let early-converging frames idle while the hardest frame
finishes, instead of serializing one jitted call per frame.

    PYTHONPATH=src python examples/batched_graphcuts.py
"""
import os
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import numpy as np

from repro.core.batch import solve_maxflow_batch
from repro.core.maxflow.grid import maxflow_grid

from graphcut_segmentation import build_grid_cut, synth_image


def request_queue():
    """Eight frames at three resolutions (a ragged mini-batch of requests)."""
    frames = []
    for i, (H, W) in enumerate([(64, 64), (64, 64), (48, 64), (64, 64),
                                (32, 32), (48, 64), (64, 64), (32, 32)]):
        img, truth = synth_image(H, W, seed=i)
        frames.append((build_grid_cut(img), truth))
    return frames


def main():
    frames = request_queue()
    probs = [p for p, _ in frames]

    # warm up both paths (first call traces + compiles), then time the
    # steady-state dispatch with the results actually materialized
    jax.block_until_ready(solve_maxflow_batch(probs, bucket="max"))
    jax.block_until_ready([maxflow_grid(p) for p in probs])

    t0 = time.perf_counter()
    results = jax.block_until_ready(solve_maxflow_batch(probs, bucket="max"))
    batch_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    solo = jax.block_until_ready([maxflow_grid(p) for p in probs])
    solo_s = time.perf_counter() - t0

    print(f"{len(frames)} frames, bucket='max' (one dispatch)")
    print(f"batched wall: {batch_s:.2f}s   "
          f"({len(frames) / batch_s:.1f} inst/s)")
    print(f"looped wall : {solo_s:.2f}s   "
          f"({len(frames) / solo_s:.1f} inst/s, one jitted call per frame)")
    for i, ((_, truth), r) in enumerate(zip(frames, results)):
        seg = ~np.asarray(r.cut)               # source side = foreground
        iou = (seg & truth).sum() / max((seg | truth).sum(), 1)
        print(f"frame {i}: shape={truth.shape} flow={float(r.flow):8.0f} "
              f"rounds={int(r.rounds):4d} converged={bool(r.converged)} "
              f"IoU={iou:.3f}")
        assert bool(r.converged)
        assert iou > 0.80, "segmentation should recover the blob"
    # the padded batched solve is the same optimum the solo solver finds
    for r, s in zip(results, solo):
        assert float(r.flow) == float(s.flow)
    print("all frames: batched flows equal solo flows")


if __name__ == "__main__":
    main()
