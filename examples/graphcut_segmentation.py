"""Graph-cut image segmentation — the paper's §4 application ([12], [4]).

Builds the Kolmogorov grid construction for a synthetic two-region image:
terminal capacities encode per-pixel fg/bg likelihood, neighbour capacities
encode smoothness, and the min cut of the max flow is the segmentation.

    PYTHONPATH=src python examples/graphcut_segmentation.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core.maxflow.grid import GridProblem, maxflow_grid


def synth_image(H=64, W=64, seed=0):
    rng = np.random.default_rng(seed)
    img = np.zeros((H, W), np.float32)
    yy, xx = np.mgrid[:H, :W]
    blob = ((yy - H * 0.45) ** 2 + (xx - W * 0.55) ** 2) < (H * 0.28) ** 2
    img[blob] = 1.0
    img += rng.normal(0, 0.30, size=img.shape)
    return np.clip(img, -0.5, 1.5), blob


def build_grid_cut(img, lam=5.0, sigma=0.30):
    """Kolmogorov construction: data term -> terminals, smoothness -> grid."""
    H, W = img.shape
    # data term: likelihood of fg (bright) / bg (dark), scaled to ints
    fg_cost = (1.0 - img).clip(0, 2) * 10
    bg_cost = img.clip(0, 2) * 10
    cap_src = np.round(bg_cost * 10).astype(np.float32)   # s->x: bg penalty
    cap_sink = np.round(fg_cost * 10).astype(np.float32)  # x->t: fg penalty
    # smoothness: contrast-weighted 4-neighbour capacities
    cap = np.zeros((4, H, W), np.float32)
    def w(a, b):
        return np.round(lam * 10 * np.exp(-(a - b) ** 2 / (2 * sigma ** 2)))
    cap[0, 1:, :] = w(img[1:, :], img[:-1, :])    # UP
    cap[1, :-1, :] = w(img[:-1, :], img[1:, :])   # DOWN
    cap[2, :, 1:] = w(img[:, 1:], img[:, :-1])    # LEFT
    cap[3, :, :-1] = w(img[:, :-1], img[:, 1:])   # RIGHT
    return GridProblem(jnp.asarray(cap), jnp.asarray(cap_src),
                       jnp.asarray(cap_sink))


def main():
    img, truth = synth_image()
    prob = build_grid_cut(img)
    res = maxflow_grid(prob)
    seg = ~np.asarray(res.cut)          # source side = foreground
    iou = (seg & truth).sum() / max((seg | truth).sum(), 1)
    print(f"max flow        : {float(res.flow):.0f}")
    print(f"rounds          : {int(res.rounds)}")
    print(f"converged       : {bool(res.converged)}")
    print(f"IoU vs truth    : {iou:.3f}")
    # ASCII rendering
    for i in range(0, img.shape[0], 4):
        row = "".join("#" if seg[i, j] else "." for j in
                      range(0, img.shape[1], 2))
        print(row)
    assert iou > 0.80, "segmentation should recover the blob"


if __name__ == "__main__":
    main()
