"""A/B: the paper's assignment solver as an MoE router vs top-k.

Trains two reduced Phi-3.5-MoE variants that differ only in
``moe.router`` and reports loss + load-balance metrics — the paper's
technique as a first-class feature of the LM stack (DESIGN.md §3).

    PYTHONPATH=src python examples/moe_flow_routing.py [--steps 60]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, smoke_variant
from repro.core.routing import auction_route, topk_route
from repro.data.pipeline import DataConfig, host_batch
from repro.models.layers import Sharder
from repro.models.model import init_model
from repro.optim.adamw import AdamWConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step


def train(router: str, steps: int):
    cfg = smoke_variant(get_config("phi3.5-moe-42b-a6.6b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, router=router,
                                     capacity_factor=1.0))
    shd = Sharder()
    params, axes = init_model(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr_peak=2e-3, warmup_steps=10, decay_steps=steps))
    state = init_train_state(cfg, tcfg, params)
    step_fn = jax.jit(make_train_step(cfg, axes, tcfg, shd))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8,
                      copy_prob=0.7)
    losses = []
    for s in range(steps):
        b = host_batch(dcfg, s, 0, 1)
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    return losses


def balance_stats():
    rng = np.random.default_rng(0)
    T, E, k = 1024, 16, 2
    cap = int(T * k / E)                 # tight capacity
    s = jnp.asarray(rng.normal(size=(T, E)).astype(np.float32))
    s = s.at[:, :3].add(2.0)             # 3 hot experts
    out = {}
    for name, fn in (("topk", topk_route), ("flow", auction_route)):
        r = fn(s, k, cap)
        d = np.asarray(r.dispatch)
        out[name] = dict(dropped=int(T * k - d.sum()),
                         load_cv=float(d.sum(0).std() / d.sum(0).mean()))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    print("routing balance under skewed logits (tight capacity):")
    for name, st in balance_stats().items():
        print(f"  {name:5s}: dropped={st['dropped']:4d} "
              f"load_cv={st['load_cv']:.3f}")

    for router in ("topk", "flow"):
        losses = train(router, args.steps)
        print(f"router={router:5s} loss {losses[0]:.3f} -> "
              f"{np.mean(losses[-5:]):.3f}")


if __name__ == "__main__":
    main()
