"""A streaming solver service — the async scheduler end to end.

A bursty client fires mixed flow/matching requests at
``repro.serve.scheduler.AsyncSolverEngine`` the way a real stream would:
no manual flushes, arrival gaps, a latency deadline per request, and
ragged instance difficulty. The background scheduler batches on size and
deadline triggers, pipelines host padding over device solves, flips to
the compacted solver-loop driver once the convergence-spread EWMA shows
the stream is ragged, and reports the whole story in one metrics
snapshot.

    PYTHONPATH=src python examples/streaming_service.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core.maxflow.grid import GridProblem
from repro.core.maxflow.ref import random_grid_problem
from repro.serve.scheduler import AsyncSolverEngine

HW = 32              # grid side for max-flow requests
N_ASSIGN = 24        # matrix size for matching requests
N_REQUESTS = 40
DEADLINE_MS = 200.0  # per-request latency budget


def make_stream(seed=0):
    """A mixed, ragged-difficulty request stream (~3 easy : 1 hard)."""
    rng = np.random.default_rng(seed)
    stream = []
    for i in range(N_REQUESTS):
        if i % 3 == 2:                     # every third request: matching
            w = rng.integers(0, 100, (N_ASSIGN, N_ASSIGN))
            if i % 4:
                w //= 25                   # easy: short eps schedule
            stream.append(("assignment", w))
        else:                              # grid cut
            cap, cs, ct = random_grid_problem(rng, HW, HW, max_cap=20,
                                              terminal_density=0.3)
            if i % 4:
                cs = np.minimum(cs, 1.0)   # easy: converges in first cycles
            stream.append(("maxflow",
                           GridProblem(*map(jnp.asarray, (cap, cs, ct)))))
    return stream


def main():
    stream = make_stream()
    t0 = time.perf_counter()
    with AsyncSolverEngine(max_batch=8, max_delay_ms=DEADLINE_MS,
                           dispatch="adaptive", spread_threshold=0.15,
                           min_compact_batch=4) as eng:
        futures = []
        for i, (kind, payload) in enumerate(stream):
            # one generic entry point for every registered solver kind
            fut = eng.submit(kind, payload, deadline_ms=DEADLINE_MS)
            futures.append((kind, fut))
            if i % 8 == 7:
                time.sleep(0.02)           # burst boundary: client breathes

        done = 0
        for kind, fut in futures:
            res = fut.result(timeout=600)  # futures, not flushes
            assert bool(res.converged), kind
            done += 1
        snap = eng.metrics.snapshot()
    wall = time.perf_counter() - t0

    print(f"served {done}/{N_REQUESTS} requests in {wall:.2f}s "
          f"({done / wall:.1f} req/s incl. compile)")
    print(f"  flush triggers : {snap['flushes_by_trigger']}")
    print(f"  dispatches     : {snap['dispatches']}")
    print(f"  ticket latency : p50={snap['latency_ms']['p50']:.0f}ms  "
          f"p99={snap['latency_ms']['p99']:.0f}ms")
    print(f"  occupancy EWMA : {snap['occupancy_ewma']}")
    print(f"  spread EWMA    : {snap['spread_ewma']}")
    if any(k.endswith(":compacted") for k in snap["dispatches"]):
        print("  -> adaptive dispatch flipped this ragged stream to the "
              "compacted driver")


if __name__ == "__main__":
    main()
