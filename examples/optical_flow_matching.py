"""Optical flow by weighted matching — the paper's §1 motivation ([18]).

Feature points from frame A are matched to frame B by solving the
assignment problem on a complete bipartite graph whose weights combine
appearance similarity and displacement priors — the paper's exact use case
(|X| = |Y| <= 30, costs <= 100, real-time budget 1/20 s).

    PYTHONPATH=src python examples/optical_flow_matching.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core.assignment.cost_scaling import solve_assignment


def main():
    rng = np.random.default_rng(0)
    n = 30
    # frame A points + descriptors
    pts_a = rng.uniform(0, 100, (n, 2))
    desc_a = rng.normal(size=(n, 8))
    # frame B: same points moved by a smooth flow + noise, shuffled
    flow = np.stack([3 + 0.05 * pts_a[:, 1], -2 + 0.03 * pts_a[:, 0]], 1)
    perm = rng.permutation(n)
    pts_b = (pts_a + flow + rng.normal(0, 0.3, (n, 2)))[perm]
    desc_b = (desc_a + rng.normal(0, 0.1, (n, 8)))[perm]

    # paper operating point: integer weights in [0, 100]
    app = -np.linalg.norm(desc_a[:, None] - desc_b[None], axis=-1)
    disp = -0.05 * np.linalg.norm(pts_a[:, None] - pts_b[None], axis=-1)
    w = app + disp
    w = np.round(100 * (w - w.min()) / (w.max() - w.min())).astype(np.int32)

    solve_assignment(jnp.asarray(w), method="auction")  # compile warmup
    t0 = time.perf_counter()
    res = solve_assignment(jnp.asarray(w), method="auction")
    assert bool(res.converged)  # else col_of_row may hold the >=n sentinel
    match = np.asarray(res.col_of_row)
    dt = time.perf_counter() - t0
    # correct match for row i is the j with perm[j] == i
    correct = np.argsort(perm)
    acc = (match == correct).mean()

    print(f"n={n} matched in {dt*1e3:.1f} ms "
          f"(paper: ~50 ms on GTX 560 Ti) — {50/max(dt*1e3,1e-9):.1f}x")
    print(f"matching accuracy: {acc:.2f}")
    print(f"total ops (push+relabel): {int(res.pushes)+int(res.relabels)}")
    est = pts_b[match] - pts_a
    err = np.linalg.norm(est - flow, axis=1)[correct == match].mean()
    print(f"mean flow error on correct matches: {err:.2f} px")
    assert acc > 0.9


if __name__ == "__main__":
    main()
