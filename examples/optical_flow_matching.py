"""Optical flow by weighted matching — the paper's §1 motivation ([18]).

Feature points from frame A are matched to frame B by solving the
assignment problem on a complete bipartite graph whose weights combine
appearance similarity and displacement priors — the paper's exact use case
(|X| = |Y| <= 30, costs <= 100, real-time budget 1/20 s).

End-to-end and BATCHED: a camera rig produces a stream of frame pairs with
ragged feature counts (trackers lose and re-detect points), and the whole
stream is solved in batched dispatches by
``repro.core.batch.solve_assignment_batch`` — pad-and-bucket over the
ragged sizes, per-instance convergence masks inside each bucket, optional
``mesh=`` sharding of the batch axis. The looped single-instance path is
timed alongside for comparison, and per-pair flows are recovered and
checked against the synthetic ground truth.

    PYTHONPATH=src python examples/optical_flow_matching.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core.assignment.cost_scaling import solve_assignment
from repro.core.batch import solve_assignment_batch


def make_frame_pair(seed: int, n: int):
    """One synthetic frame pair: n tracked points under a smooth flow.

    Returns integer matching weights (the paper's operating point: weights
    in [0, 100]), the permutation mapping A-rows to shuffled B-rows, the
    frame-A points, and the true flow field.
    """
    rng = np.random.default_rng(seed)
    pts_a = rng.uniform(0, 100, (n, 2))
    desc_a = rng.normal(size=(n, 8))
    # frame B: the same points moved by a smooth affine-ish flow + noise,
    # observed in arbitrary (shuffled) detector order
    flow = np.stack([3 + 0.05 * pts_a[:, 1], -2 + 0.03 * pts_a[:, 0]], 1)
    perm = rng.permutation(n)
    pts_b = (pts_a + flow + rng.normal(0, 0.3, (n, 2)))[perm]
    desc_b = (desc_a + rng.normal(0, 0.1, (n, 8)))[perm]

    app = -np.linalg.norm(desc_a[:, None] - desc_b[None], axis=-1)
    disp = -0.05 * np.linalg.norm(pts_a[:, None] - pts_b[None], axis=-1)
    w = app + disp
    w = np.round(100 * (w - w.min()) / (w.max() - w.min())).astype(np.int32)
    return w, perm, pts_a, pts_b, flow


def main():
    # a ragged stream of matching requests: detectors report 18-30 points
    sizes = [30, 24, 30, 18, 24, 30, 18, 24]
    pairs = [make_frame_pair(seed, n) for seed, n in enumerate(sizes)]
    ws = [w for w, *_ in pairs]

    # batched path: ONE dispatch per bucket (pow2 keeps the compile cache
    # stable as new sizes stream in)
    solve_assignment_batch(ws, bucket="pow2", method="auction")  # warmup
    t0 = time.perf_counter()
    results = solve_assignment_batch(ws, bucket="pow2", method="auction")
    jax.block_until_ready([r.col_of_row for r in results])
    batch_ms = (time.perf_counter() - t0) * 1e3

    # looped single-instance path (one jitted call per pair)
    for w in ws:
        solve_assignment(np.asarray(w), method="auction")  # warmup per shape
    t0 = time.perf_counter()
    solo = [solve_assignment(np.asarray(w), method="auction") for w in ws]
    jax.block_until_ready([r.col_of_row for r in solo])
    solo_ms = (time.perf_counter() - t0) * 1e3

    print(f"{len(ws)} frame pairs (ragged n={sorted(set(sizes))}), "
          f"bucket='pow2'")
    print(f"batched wall: {batch_ms:7.1f} ms "
          f"({len(ws) / batch_ms * 1e3:6.1f} pairs/s)")
    print(f"looped wall : {solo_ms:7.1f} ms "
          f"({len(ws) / solo_ms * 1e3:6.1f} pairs/s)  "
          f"[paper: ~50 ms/pair on a GTX 560 Ti]")

    total_acc = []
    for (w, perm, pts_a, pts_b, flow), r, s in zip(pairs, results, solo):
        n = w.shape[0]
        assert bool(r.converged)
        match = np.asarray(r.col_of_row)
        # the batched+padded solve recovers the same matching weight as the
        # direct single solve (bonus-shifted padding is optimum-preserving)
        assert int(r.weight) == int(s.weight)
        correct = np.argsort(perm)         # row i's true partner in frame B
        acc = float((match == correct).mean())
        total_acc.append(acc)
        est = pts_b[match] - pts_a         # recovered flow vectors
        good = match == correct
        err = np.linalg.norm(est - flow, axis=1)[good].mean()
        print(f"  n={n:2d}  accuracy={acc:.2f}  "
              f"mean flow error (correct matches)={err:.2f} px  "
              f"ops={int(r.pushes) + int(r.relabels)}")
    assert np.mean(total_acc) > 0.9, "matching should recover the flow"
    print(f"mean accuracy over the stream: {np.mean(total_acc):.2f}")


if __name__ == "__main__":
    main()
