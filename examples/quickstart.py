"""Quickstart: train a reduced SmolLM for a few hundred steps, then sample.

    PYTHONPATH=src python examples/quickstart.py [--steps 200]

Uses the same public API the production launcher uses (configs, init_model,
make_train_step, greedy_generate) at laptop scale.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, smoke_variant
from repro.data.pipeline import DataConfig, host_batch
from repro.models.layers import Sharder
from repro.models.model import init_model
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import greedy_generate
from repro.train.step import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = smoke_variant(get_config("smollm-135m"))
    shd = Sharder()
    params, axes = init_model(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} ({n/1e6:.2f}M params)")

    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr_peak=3e-3, warmup_steps=20, decay_steps=args.steps))
    state = init_train_state(cfg, tcfg, params)
    step_fn = jax.jit(make_train_step(cfg, axes, tcfg, shd))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8,
                      copy_prob=0.7)

    t0 = time.time()
    for s in range(args.steps):
        b = host_batch(dcfg, s, 0, 1)
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        if s % 20 == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss {float(m['loss']):.4f} "
                  f"({(s+1)*dcfg.global_batch*dcfg.seq_len/(time.time()-t0):,.0f} tok/s)")

    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)), jnp.int32)
    out = greedy_generate(cfg, state.params, axes, shd, prompts, max_new=12)
    print("greedy samples (token ids):")
    for row in np.asarray(out):
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
