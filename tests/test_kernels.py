"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.maxflow.grid import (GridFlowState, bfs_heights,
                                     jacobi_round)
from repro.core.maxflow.ref import random_grid_problem
from repro.kernels.bidding.kernel import bidding
from repro.kernels.bidding.ref import bidding_ref
from repro.kernels.grid_push.kernel import grid_push_decide
from repro.kernels.grid_push.ref import grid_push_decide_ref
from repro.kernels.grid_push.ops import jacobi_round_pallas


@pytest.mark.parametrize("shape,blocks", [
    ((8, 8), (8, 8)),
    ((64, 128), (16, 32)),
    ((256, 512), (128, 128)),
    ((128, 128), (128, 64)),
    ((32, 1024), (32, 256)),
])
def test_bidding_kernel_sweep(shape, blocks):
    rng = np.random.default_rng(hash(shape) % 2**31)
    n_r, n_c = shape
    c = jnp.asarray(rng.integers(-1000, 1000, (n_r, n_c)), jnp.int32)
    p = jnp.asarray(rng.integers(-500, 500, (n_c,)), jnp.int32)
    m = jnp.asarray(rng.random((n_r, n_c)) < 0.3)
    got = bidding(c, p, m, block_rows=blocks[0], block_cols=blocks[1],
                  interpret=True)
    ref = bidding_ref(c, p, m)
    for g, r, nm in zip(got, ref, ["min1", "arg1", "min2"]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r),
                                      err_msg=nm)


def test_bidding_fully_masked_rows():
    c = jnp.zeros((8, 8), jnp.int32)
    p = jnp.zeros((8,), jnp.int32)
    m = jnp.ones((8, 8), bool)
    min1, _, min2 = bidding(c, p, m, block_rows=8, block_cols=8,
                            interpret=True)
    assert bool(jnp.all(min1 >= 2 ** 30)) and bool(jnp.all(min2 >= 2 ** 30))


@pytest.mark.parametrize("H,W,bh,bw", [(8, 8, 8, 8), (16, 32, 8, 16),
                                       (32, 32, 16, 32)])
def test_grid_push_kernel_vs_ref(H, W, bh, bw):
    rng = np.random.default_rng(0)
    cap, cs, ct = random_grid_problem(rng, H, W)
    st = GridFlowState(
        e=jnp.asarray(cs), h=jnp.zeros((H, W), jnp.int32),
        cap=jnp.asarray(cap), cap_src=jnp.asarray(cs),
        cap_sink=jnp.asarray(ct), sink_flow=jnp.float32(0),
        src_flow=jnp.float32(0))
    n = jnp.int32(H * W + 2)
    st = st._replace(h=bfs_heights(st.cap, st.cap_sink, st.h, n, H * W + 2))
    nbr_h = jnp.stack([jnp.roll(st.h, 1, 0)] * 4)  # placeholder, use ref path
    from repro.core.maxflow.grid import _nbr_h
    nbr_h = jnp.stack([_nbr_h(st.h, d) for d in range(4)], axis=0)
    h_k, d_k = grid_push_decide(st.e, st.h, st.cap, nbr_h, st.cap_src,
                                st.cap_sink, n, block_h=bh, block_w=bw,
                                interpret=True)
    h_r, d_r = grid_push_decide_ref(st.e, st.h, st.cap, nbr_h, st.cap_src,
                                    st.cap_sink, n)
    np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_r))
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r))


def test_grid_push_kernel_batched_grid():
    """Batched mode (pallas grid gains a batch dim) == per-instance kernel."""
    rng = np.random.default_rng(7)
    B, H, W = 3, 16, 16
    probs = [random_grid_problem(rng, H, W) for _ in range(B)]
    e = jnp.asarray(np.stack([p[1] for p in probs]))
    cap = jnp.asarray(np.stack([p[0] for p in probs], axis=1))  # (4, B, H, W)
    ct = jnp.asarray(np.stack([p[2] for p in probs]))
    n = jnp.int32(H * W + 2)
    h = bfs_heights(cap, ct, jnp.zeros((B, H, W), jnp.int32), n, H * W + 2)
    from repro.core.maxflow.grid import _nbr_h
    nbr_h = jnp.stack([_nbr_h(h, d) for d in range(4)], axis=0)
    h_b, d_b = grid_push_decide(e, h, cap, nbr_h, e, ct, n,
                                block_h=8, block_w=8, interpret=True)
    for b in range(B):
        h_s, d_s = grid_push_decide(
            e[b], h[b], cap[:, b], nbr_h[:, b], e[b], ct[b], n,
            block_h=8, block_w=8, interpret=True)
        np.testing.assert_array_equal(np.asarray(h_b[b]), np.asarray(h_s))
        np.testing.assert_array_equal(np.asarray(d_b[:, b]), np.asarray(d_s))


def test_grid_push_round_bit_identical():
    """Full Jacobi rounds via the kernel == pure-jnp rounds, 5 steps."""
    rng = np.random.default_rng(1)
    H, W = 16, 16
    cap, cs, ct = random_grid_problem(rng, H, W)
    st = GridFlowState(
        e=jnp.asarray(cs), h=jnp.zeros((H, W), jnp.int32),
        cap=jnp.asarray(cap), cap_src=jnp.asarray(cs),
        cap_sink=jnp.asarray(ct), sink_flow=jnp.float32(0),
        src_flow=jnp.float32(0))
    n = jnp.int32(H * W + 2)
    st = st._replace(h=bfs_heights(st.cap, st.cap_sink, st.h, n, H * W + 2))
    for _ in range(5):
        a = jacobi_round(st, n)
        b = jacobi_round_pallas(st, n, block_h=8, block_w=8, interpret=True)
        for fa, fb, nm in zip(a, b, a._fields):
            if fa is None and fb is None:  # heur counter untracked here
                continue
            np.testing.assert_allclose(np.asarray(fa), np.asarray(fb),
                                       err_msg=nm)
        st = a
