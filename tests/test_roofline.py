"""Roofline HLO analyzer: trip-count accounting on a known workload."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline_hlo import analyze, multipliers, parse_computations
from repro.roofline import Roofline, cost_analysis_dict, model_flops_for
from repro.configs.base import get_config


def test_scan_trip_counts_accounted():
    """A 10-trip scan of 512^3 matmuls must report ~10 matmuls of FLOPs."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    sds = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    compiled = jax.jit(f).lower(sds, sds).compile()
    acc = analyze(compiled.as_text())
    expect = 10 * 2 * 512 ** 3
    assert 0.9 * expect <= acc["flops"] <= 1.3 * expect, acc["flops"]
    # cost_analysis undercounts by ~the trip count (the bug we work around);
    # cost_analysis_dict normalizes the list-vs-dict return across jax versions
    ca = cost_analysis_dict(compiled)
    assert ca["flops"] < 0.2 * expect


def test_nested_scan_multipliers():
    def f(x, w):
        def outer(c, _):
            def inner(d, _):
                return d @ w, None
            d, _ = jax.lax.scan(inner, c, None, length=4)
            return d, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(sds, sds).compile()
    acc = analyze(compiled.as_text())
    expect = 12 * 2 * 128 ** 3
    assert 0.9 * expect <= acc["flops"] <= 1.3 * expect


def test_collective_parse():
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        y = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P("d", None)))
        return jnp.sum(y)

    sds = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    with mesh:
        compiled = jax.jit(f).lower(sds).compile()
    acc = analyze(compiled.as_text())   # 1-device: no collectives expected
    assert acc["collective_bytes"] >= 0.0


def test_roofline_terms_and_bottleneck():
    rl = Roofline(arch="x", shape="train_4k", mesh="16x16", chips=256,
                  flops=197e12, bytes_accessed=819e9 * 2,
                  coll_bytes=50e9 * 0.5, coll_breakdown={},
                  model_flops=197e12 * 256 * 0.25, bytes_per_chip=1e9)
    assert abs(rl.t_compute - 1.0) < 1e-9
    assert abs(rl.t_memory - 2.0) < 1e-9
    assert abs(rl.t_collective - 0.5) < 1e-9
    assert rl.bottleneck == "memory"
    assert abs(rl.roofline_frac - 0.125) < 1e-9


def test_model_flops_formula():
    cfg = get_config("smollm-135m")
    info = dict(kind="train", seq_len=4096, global_batch=256)
    mf = model_flops_for(cfg, info)
    assert abs(mf - 6 * cfg.param_count() * 4096 * 256) / mf < 1e-9
    dec = model_flops_for(cfg, dict(kind="decode", seq_len=32768,
                                    global_batch=128))
    assert dec == 2.0 * cfg.active_param_count() * 128
