"""Documentation snippets must execute — README/docs code cannot rot.

Every fenced ```python block in README.md and docs/*.md is extracted at
collection time and exec'd as its own test (CI's docs job runs exactly
this file; see .github/workflows/ci.yml). Keep doc snippets small and
self-contained: each runs in a fresh namespace with no setup.
"""
from __future__ import annotations

import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))

_FENCE = re.compile(r"```python\n(.*?)```", re.S)


def _snippets():
    out = []
    for path in DOC_FILES:
        for i, src in enumerate(_FENCE.findall(path.read_text())):
            out.append(pytest.param(
                path, src, id=f"{path.relative_to(ROOT)}#{i}"))
    return out


def test_docs_exist_and_have_snippets():
    assert (ROOT / "README.md").is_file()
    assert (ROOT / "docs" / "batching.md").is_file()
    assert len(_snippets()) >= 3, "docs lost their executable examples"


@pytest.mark.parametrize("path,src", _snippets())
def test_doc_snippet_executes(path, src):
    code = compile(src, f"{path.name}:snippet", "exec")
    exec(code, {"__name__": "__doc_snippet__"})
