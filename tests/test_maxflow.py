"""Grid push-relabel max-flow vs scipy oracle + invariants (paper §4)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-hypothesis shim

from repro.core.maxflow.grid import (GridProblem, check_no_violations,
                                     maxflow_grid)
from repro.core.maxflow.ref import maxflow_grid_ref, random_grid_problem


@pytest.mark.parametrize("seed", range(5))
def test_maxflow_matches_scipy(seed):
    rng = np.random.default_rng(seed)
    H, W = rng.integers(2, 9, 2)
    cap, cs, ct = random_grid_problem(rng, int(H), int(W))
    ref = maxflow_grid_ref(cap, cs, ct)
    res = maxflow_grid(GridProblem(jnp.asarray(cap), jnp.asarray(cs),
                                   jnp.asarray(ct)))
    assert bool(res.converged)
    assert abs(float(res.flow) - ref) < 1e-4
    assert bool(check_no_violations(res.state))


def test_maxflow_32x32():
    rng = np.random.default_rng(42)
    cap, cs, ct = random_grid_problem(rng, 32, 32, max_cap=20,
                                      terminal_density=0.3)
    ref = maxflow_grid_ref(cap, cs, ct)
    res = maxflow_grid(GridProblem(jnp.asarray(cap), jnp.asarray(cs),
                                   jnp.asarray(ct)))
    assert abs(float(res.flow) - ref) < 1e-3


def test_maxflow_pallas_backend_matches():
    rng = np.random.default_rng(3)
    cap, cs, ct = random_grid_problem(rng, 8, 8)
    a = maxflow_grid(GridProblem(jnp.asarray(cap), jnp.asarray(cs),
                                 jnp.asarray(ct)))
    b = maxflow_grid(GridProblem(jnp.asarray(cap), jnp.asarray(cs),
                                 jnp.asarray(ct)), backend="pallas")
    assert float(a.flow) == float(b.flow)


def test_min_cut_separates():
    """Cut labels: cut edges' capacities sum to the flow value (duality)."""
    rng = np.random.default_rng(7)
    cap, cs, ct = random_grid_problem(rng, 6, 6)
    res = maxflow_grid(GridProblem(jnp.asarray(cap), jnp.asarray(cs),
                                   jnp.asarray(ct)))
    cut = np.asarray(res.cut)           # True = sink side
    # source-side -> sink-side original capacities + terminal crossings
    total = 0.0
    H, W = cut.shape
    for i in range(H):
        for j in range(W):
            if not cut[i, j]:           # source side
                total += float(ct[i, j])        # x -> t crossing
                for d, (di, dj) in enumerate([(-1, 0), (1, 0), (0, -1),
                                              (0, 1)]):
                    ii, jj = i + di, j + dj
                    if 0 <= ii < H and 0 <= jj < W and cut[ii, jj]:
                        total += float(cap[d, i, j])
            else:
                total += float(cs[i, j])        # s -> x crossing
    assert abs(total - float(res.flow)) < 1e-3


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6), st.integers(2, 6))
def test_maxflow_property(seed, H, W):
    """Property: flow value == scipy's for random instances; heights valid."""
    rng = np.random.default_rng(seed)
    cap, cs, ct = random_grid_problem(rng, H, W, max_cap=7)
    ref = maxflow_grid_ref(cap, cs, ct)
    res = maxflow_grid(GridProblem(jnp.asarray(cap), jnp.asarray(cs),
                                   jnp.asarray(ct)))
    assert abs(float(res.flow) - ref) < 1e-4
    assert bool(check_no_violations(res.state))
    # conservation: every interior excess drained
    assert float(jnp.sum(jnp.maximum(res.state.e, 0))) < 1e-4


def test_maxflow_multipush_backend():
    """Beyond-paper multipush variant: same flow value (rounds: see
    EXPERIMENTS.md §Perf — the round-reduction hypothesis was refuted)."""
    rng = np.random.default_rng(11)
    cap, cs, ct = random_grid_problem(rng, 8, 8)
    ref = maxflow_grid_ref(cap, cs, ct)
    r = maxflow_grid(GridProblem(jnp.asarray(cap), jnp.asarray(cs),
                                 jnp.asarray(ct)), backend="multipush")
    assert abs(float(r.flow) - ref) < 1e-4
