"""Flash attention (custom VJP) vs dense reference: values and gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import _flash_attend


def _dense_ref(q, k, v, causal, scale):
    H, KV = q.shape[2], k.shape[2]
    G = H // KV
    kk = jnp.repeat(k, G, 2)
    vv = jnp.repeat(v, G, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * scale
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        m = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("dims", [(2, 64, 6, 2, 16, 16), (1, 32, 4, 4, 8, 4)])
def test_flash_matches_dense(causal, chunk, dims):
    B, S, H, KV, dh, dv = dims
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, dv)).astype(np.float32))
    scale = dh ** -0.5
    out = _flash_attend(q, k, v, causal=causal, scale=scale, chunk=chunk)
    ref = _dense_ref(q, k, v, causal, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)

    f = lambda *a: _flash_attend(*a, causal=causal, scale=scale,
                                 chunk=chunk).sum()
    g = lambda *a: _dense_ref(*a, causal, scale).sum()
    ga = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b, nm in zip(ga, gb, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5, err_msg=f"d{nm}")


def test_flash_bf16_stability():
    B, S, H, KV, dh = 2, 128, 4, 2, 32
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.bfloat16) * 4
    k = jnp.asarray(rng.normal(size=(B, S, KV, dh)), jnp.bfloat16) * 4
    v = jnp.asarray(rng.normal(size=(B, S, KV, dh)), jnp.bfloat16)
    out = _flash_attend(q, k, v, causal=True, scale=dh ** -0.5, chunk=32)
    assert out.dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
