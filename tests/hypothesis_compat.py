"""Optional-``hypothesis`` shim so the suite collects offline.

Property-based tests import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly. When hypothesis is installed the real
objects pass through untouched; when it is not (offline/minimal
environments), ``@given`` turns the test into a single skip and the rest of
the module's tests still collect and run.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade property tests to skips, keep the module alive
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # NOT functools.wraps: pytest must see a zero-arg signature,
            # not the strategy-driven parameters of the wrapped property.
            def wrapper():
                pytest.skip("hypothesis not installed")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategy:
        """Chainable stub so idioms like st.integers(0, 5).map(str) still
        evaluate at decoration time (the strategies are never drawn from)."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _Strategy()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
