"""backend="balanced": stall-driven relabel + active-tile scheduling.

The contracts under test:

* CORRECTNESS — balanced (and every other backend) matches the scipy
  oracle on the adversarial generator families the backend was built to
  beat (``repro.core.maxflow.ref.ADVERSARIAL_GENERATORS``);
* DETERMINISM — balanced keeps the per-instance purity contract: batched
  == compacted == loop-of-singles, bit-exact, including the new
  ``heuristics`` counter (and sharded, when devices allow — the slow
  subprocess test relaunches this file under 8 emulated host devices);
* INVARIANT — ``check_no_violations`` holds after EVERY heuristic
  invocation: cutting a solve off at ``k * rounds_per_heuristic`` rounds
  lands exactly after the k-th relabel opportunity, so sweeping k probes
  the state right where the bidirectional BFS relabel just ran;
* THE WIN — balanced needs strictly fewer rounds than xla's fixed-cadence
  relabel on the checkerboard family (benchmarks/RESULTS_adversarial.md
  has the full matrix);
* S1 — unknown backends raise ``ValueError`` naming the valid set.
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-hypothesis shim

from repro.core.batch import solve_maxflow_batch, stack_grid_problems
from repro.core.maxflow.grid import (VALID_BACKENDS, GridProblem, _round_fn,
                                     check_no_violations, maxflow_grid,
                                     maxflow_grid_batch)
from repro.core.maxflow.ref import (ADVERSARIAL_GENERATORS, maxflow_grid_ref,
                                    random_grid_problem)
from repro.launch.mesh import make_solver_mesh

N_DEV = len(jax.devices())
FORCE_FLAG = "--xla_force_host_platform_device_count=8"
multi = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices; covered via the subprocess test")
SHARD_COUNTS = sorted({2, N_DEV}) if N_DEV >= 2 else []

BACKENDS = list(VALID_BACKENDS)


def _problem(gname, H, W, seed=0):
    cap, cs, ct = ADVERSARIAL_GENERATORS[gname](
        np.random.default_rng(seed), H, W)
    return GridProblem(*map(jnp.asarray, (cap, cs, ct))), (cap, cs, ct)


# --------------------------------------------------------- S1: backend knob

def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown maxflow backend 'nope'"):
        _round_fn("nope")
    with pytest.raises(ValueError, match="balanced"):
        maxflow_grid(_problem("checkerboard", 4, 4)[0], backend="nope")


# ------------------------------------------------- oracle equality, all gens

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("gname", sorted(ADVERSARIAL_GENERATORS))
def test_adversarial_matches_oracle(gname, backend):
    prob, (cap, cs, ct) = _problem(gname, 16, 16)
    ref = maxflow_grid_ref(cap, cs, ct)
    res = maxflow_grid(prob, backend=backend, max_rounds=500_000)
    assert bool(res.converged)
    assert abs(float(res.flow) - ref) < 1e-4, (gname, backend)
    assert bool(check_no_violations(res.state))


def test_balanced_random_grids_match_oracle():
    for seed in range(4):
        rng = np.random.default_rng(seed)
        cap, cs, ct = random_grid_problem(rng, 12, 12)
        ref = maxflow_grid_ref(cap, cs, ct)
        res = maxflow_grid(GridProblem(*map(jnp.asarray, (cap, cs, ct))),
                           backend="balanced")
        assert bool(res.converged)
        assert abs(float(res.flow) - ref) < 1e-4


# ------------------------------------------------------------------ the win

def test_balanced_beats_xla_rounds_on_checkerboard():
    """The acceptance headline at test scale: >=2x fewer rounds at 32**2."""
    prob, _ = _problem("checkerboard", 32, 32)
    r_xla = maxflow_grid(prob, backend="xla", max_rounds=500_000)
    r_bal = maxflow_grid(prob, backend="balanced", max_rounds=500_000)
    assert bool(r_xla.converged) and bool(r_bal.converged)
    assert float(r_xla.flow) == float(r_bal.flow)
    assert int(r_bal.rounds) * 2 <= int(r_xla.rounds), \
        (int(r_bal.rounds), int(r_xla.rounds))
    # the stall trigger is why: strictly fewer relabel invocations too
    assert int(r_bal.heuristics) < int(r_xla.heuristics)


def test_fixed_cadence_heuristics_counter():
    """xla's counter must equal the number of completed cycles exactly."""
    prob, _ = _problem("checkerboard", 8, 8)
    res = maxflow_grid(prob, backend="xla", rounds_per_heuristic=8)
    assert int(res.heuristics) == (int(res.rounds) + 7) // 8


# ------------------------------------------ determinism: batched == singles

@pytest.mark.parametrize("compact", [False, True])
def test_balanced_batched_bitmatches_singles(compact):
    probs = [_problem(g, 8, 8, seed=s)[0]
             for s in range(2) for g in sorted(ADVERSARIAL_GENERATORS)]
    batch = stack_grid_problems(probs)
    res = maxflow_grid_batch(batch, backend="balanced", compact=compact)
    for b, p in enumerate(probs):
        single = maxflow_grid(p, backend="balanced")
        assert float(res.flow[b]) == float(single.flow)
        assert int(res.rounds[b]) == int(single.rounds)
        assert int(res.heuristics[b]) == int(single.heuristics)
        np.testing.assert_array_equal(np.asarray(res.cut[b]),
                                      np.asarray(single.cut))
        np.testing.assert_array_equal(np.asarray(res.state.h[b]),
                                      np.asarray(single.state.h))
        np.testing.assert_array_equal(np.asarray(res.state.e[b]),
                                      np.asarray(single.state.e))


@multi
def test_balanced_sharded_bitmatches_unsharded():
    gens = sorted(ADVERSARIAL_GENERATORS)
    probs = [_problem(gens[i % len(gens)], 8, 8, seed=i)[0]
             for i in range(8)]      # 8 instances: divisible by every lane
    batch = stack_grid_problems(probs)
    base = maxflow_grid_batch(batch, backend="balanced")
    for s in SHARD_COUNTS:
        shard = maxflow_grid_batch(batch, backend="balanced", compact=True,
                                   mesh=make_solver_mesh(s))
        for name, la, lb in zip(base._fields, base, shard):
            if isinstance(la, tuple):
                la, lb = jnp.asarray(la.e), jnp.asarray(lb.e)
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                          err_msg=name)


@pytest.mark.slow  # full balanced suite again in a fresh 8-dev process
@pytest.mark.skipif(N_DEV >= 2, reason="already multi-device")
def test_forced_multi_device_subprocess():
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", str(__file__),
         "-k", "sharded"],
        cwd=root, env=env, capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, f"\n--- stdout ---\n{r.stdout}\n{r.stderr}"
    assert "passed" in r.stdout


# ----------------------------------------------- S2: stats plumbing surfaces

def test_bucket_stats_carry_heuristics():
    probs = [_problem("checkerboard", 8, 8)[0],
             _problem("long_path", 8, 8)[0]]
    stats_out: list = []
    res = solve_maxflow_batch(probs, backend="balanced", stats_out=stats_out)
    [stats] = stats_out
    assert stats.heur_min is not None and stats.heur_max is not None
    assert stats.heur_min <= stats.heur_mean <= stats.heur_max
    assert {int(r.heuristics) for r in res} \
        >= {stats.heur_min, stats.heur_max}


def test_metrics_snapshot_has_rounds_and_heuristics():
    from repro.serve.metrics import SchedulerMetrics
    m = SchedulerMetrics()
    m.record_dispatch("maxflow", compact=False, spread=0.5, occupancy=1.0,
                      rounds=96.0, heuristics=3.0)
    snap = m.snapshot()
    assert snap["rounds_ewma"]["maxflow"] == 96.0
    assert snap["heuristics_ewma"]["maxflow"] == 3.0


# ------------------------- S3: invariant after every heuristic invocation

def _invariant_after_each_heuristic(prob, backend, rph=8, cycles=6):
    """Stop the solve after k cycles for k=1..cycles: the returned state is
    exactly the state right after the k-th relabel opportunity ran."""
    for k in range(1, cycles + 1):
        res = maxflow_grid(prob, backend=backend, rounds_per_heuristic=rph,
                           max_rounds=k * rph)
        assert bool(check_no_violations(res.state)), (backend, k)
        if bool(res.converged):
            break


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("gname", sorted(ADVERSARIAL_GENERATORS))
def test_no_violations_after_each_heuristic_fixed_seeds(gname, backend):
    """Fixed-seed fallback for the hypothesis property below."""
    prob, _ = _problem(gname, 8, 8, seed=1)
    _invariant_after_each_heuristic(prob, backend)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from(sorted(ADVERSARIAL_GENERATORS)),
       st.sampled_from(BACKENDS),
       st.integers(4, 10), st.integers(4, 10))
def test_no_violations_property(seed, gname, backend, H, W):
    """Property: the height invariant survives every heuristic invocation,
    for every backend, on every adversarial family at random shapes."""
    prob, _ = _problem(gname, H, W, seed=seed)
    _invariant_after_each_heuristic(prob, backend, rph=4, cycles=5)
