"""Early-exit compaction: compacted == masked == loop of single solves.

The contract under test (repro.core.solver_loop + the ``compact=`` knob):
gathering still-live instances into dense pow2-sized sub-batches between
jitted cycle segments changes WHICH instances pay FLOPs each cycle, never
WHAT any instance computes — cycles are per-instance pure, so compacted
results bit-match the masked select-freeze path, which bit-matches a loop
of single-instance solves. This must hold for both solvers, through the
ragged pad-and-bucket front end, under per-shard device lanes (``mesh=``),
and at the serve engine.

Multi-device is emulated on CPU exactly as in test_shard.py: a slow
subprocess test relaunches this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; CI runs the file
directly with the flag exported.
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.assignment.cost_scaling import solve_assignment
from repro.core.batch import (solve_assignment_batch, solve_maxflow_batch,
                              stack_grid_problems)
from repro.core.maxflow.grid import GridProblem, maxflow_grid, \
    maxflow_grid_batch
from repro.core.maxflow.ref import random_grid_problem
from repro.core.solver_loop import bucket_size
from repro.launch.mesh import compact_lanes, make_solver_mesh
from repro.serve.engine import SolverEngine

N_DEV = len(jax.devices())
FORCE_FLAG = "--xla_force_host_platform_device_count=8"
multi = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices; covered via the subprocess test")
SHARD_COUNTS = sorted({2, N_DEV}) if N_DEV >= 2 else []


def _ragged_grid_problems(seed, B, H, W):
    """Grid instances with deliberately ragged convergence: most are easy
    (tiny excess, converge in the first cycles), a few carry full load."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(B):
        cap, cs, ct = random_grid_problem(rng, H, W)
        if i % 4:                       # 3 of every 4 instances are easy
            cs = np.minimum(cs, 1.0)
        out.append(GridProblem(*map(jnp.asarray, (cap, cs, ct))))
    return out


def _ragged_ws(seed, B, n):
    """Weight matrices with ragged ε schedules (instance difficulty varies)."""
    ws = np.stack([np.random.default_rng(seed + i).integers(0, 101, (n, n))
                   for i in range(B)])
    ws[::3] //= 9                       # short schedules for every third
    return ws


def _assert_trees_equal(a, b):
    for name, la, lb in zip(a._fields, a, b):
        if isinstance(la, tuple):  # nested NamedTuple (GridFlowState)
            _assert_trees_equal(la, lb)
        else:
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb), err_msg=name)


@pytest.mark.slow  # ~1 min: full compaction suite in a fresh 8-dev process
@pytest.mark.skipif(N_DEV >= 2, reason="already multi-device")
def test_forced_multi_device_subprocess():
    """Relaunch this file under 8 emulated host devices and require green."""
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", str(__file__)],
        cwd=root, env=env, capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, f"\n--- stdout ---\n{r.stdout}\n{r.stderr}"
    assert "passed" in r.stdout


def test_bucket_size_ladder():
    """pow2 ladder, clamped to the lane size: bounds distinct compiles."""
    assert [bucket_size(n, 8) for n in (1, 2, 3, 4, 5, 7, 8)] \
        == [1, 2, 4, 4, 8, 8, 8]
    assert bucket_size(3, 5) == 4 and bucket_size(5, 5) == 5
    assert bucket_size(1, 1) == 1


@pytest.mark.parametrize("backend", ["xla", "multipush"])
def test_maxflow_compact_bitmatches_masked_and_single(backend):
    probs = _ragged_grid_problems(0, 6, 8, 8)
    batch = stack_grid_problems(probs)
    masked = maxflow_grid_batch(batch, backend=backend)
    comp = maxflow_grid_batch(batch, backend=backend, compact=True)
    _assert_trees_equal(comp, masked)
    assert int(jnp.max(comp.rounds)) > int(jnp.min(comp.rounds)), \
        "convergence not ragged — compaction path untested"
    for b, p in enumerate(probs):
        rs = maxflow_grid(p, backend=backend)
        assert float(comp.flow[b]) == float(rs.flow)
        assert int(comp.rounds[b]) == int(rs.rounds)
        np.testing.assert_array_equal(np.asarray(comp.cut[b]),
                                      np.asarray(rs.cut))
        np.testing.assert_array_equal(np.asarray(comp.state.e[b]),
                                      np.asarray(rs.state.e))


@pytest.mark.parametrize("method", ["pushrelabel", "auction"])
def test_assignment_compact_bitmatches_masked_and_single(method):
    ws = _ragged_ws(0, 5, 10)
    masked = solve_assignment(jnp.asarray(ws), method=method)
    comp = solve_assignment(jnp.asarray(ws), method=method, compact=True)
    _assert_trees_equal(comp, masked)
    for b in range(ws.shape[0]):
        rs = solve_assignment(jnp.asarray(ws[b]), method=method)
        np.testing.assert_array_equal(np.asarray(comp.col_of_row[b]),
                                      np.asarray(rs.col_of_row))
        np.testing.assert_array_equal(np.asarray(comp.p_x[b]),
                                      np.asarray(rs.p_x))
        assert int(comp.rounds[b]) == int(rs.rounds)
        assert int(comp.pushes[b]) == int(rs.pushes)


def test_assignment_compact_requires_batch():
    w = jnp.asarray(np.random.default_rng(0).integers(0, 9, (5, 5)))
    with pytest.raises(ValueError, match="batched"):
        solve_assignment(w, compact=True)


def test_compact_unconverged_max_rounds():
    """Instances that hit max_rounds leave the live set through the rounds
    cap, not convergence — identical flags and partial state either way."""
    probs = _ragged_grid_problems(1, 4, 8, 8)
    batch = stack_grid_problems(probs)
    kw = dict(max_rounds=2, rounds_per_heuristic=2)
    masked = maxflow_grid_batch(batch, **kw)
    comp = maxflow_grid_batch(batch, compact=True, **kw)
    _assert_trees_equal(comp, masked)
    assert not bool(jnp.all(comp.converged))   # the cap actually bit


@pytest.mark.parametrize("bucket", ["max", "pow2"])
def test_ragged_front_end_compact(bucket):
    rng = np.random.default_rng(2)
    shapes = [(5, 5), (8, 8), (4, 7), (8, 8), (5, 5)]
    probs = [GridProblem(*map(jnp.asarray, random_grid_problem(rng, h, w)))
             for h, w in shapes]
    base = solve_maxflow_batch(probs, bucket=bucket)
    comp = solve_maxflow_batch(probs, bucket=bucket, compact=True)
    for a, b in zip(comp, base):
        _assert_trees_equal(a, b)

    ws = [np.random.default_rng(i).integers(-30, 71, (n, n))
          for i, n in enumerate([4, 9, 6, 9, 5])]
    for a, b in zip(solve_assignment_batch(ws, bucket=bucket, compact=True),
                    solve_assignment_batch(ws, bucket=bucket)):
        _assert_trees_equal(a, b)


@multi
def test_maxflow_compact_sharded_bitmatch():
    """Per-shard lanes: compaction within each device's slice bit-matches
    the unsharded masked solve."""
    probs = _ragged_grid_problems(3, 8, 8, 8)
    batch = stack_grid_problems(probs)
    base = maxflow_grid_batch(batch)
    for s in SHARD_COUNTS:
        comp = maxflow_grid_batch(batch, compact=True,
                                  mesh=make_solver_mesh(s))
        _assert_trees_equal(comp, base)


@multi
def test_assignment_compact_sharded_bitmatch():
    ws = _ragged_ws(5, 8, 10)
    base = solve_assignment(jnp.asarray(ws))
    for s in SHARD_COUNTS:
        comp = solve_assignment(jnp.asarray(ws), compact=True,
                                mesh=make_solver_mesh(s))
        _assert_trees_equal(comp, base)


@multi
def test_ragged_front_end_compact_sharded():
    """Ragged queue sizes shard via inert padding, then compact per lane
    (the inert pad instances are the FIRST to leave the live set)."""
    rng = np.random.default_rng(4)
    shapes = [(5, 5), (8, 8), (4, 7), (8, 8), (5, 5)]
    probs = [GridProblem(*map(jnp.asarray, random_grid_problem(rng, h, w)))
             for h, w in shapes]
    base = solve_maxflow_batch(probs, bucket="max")
    for s in SHARD_COUNTS:
        comp = solve_maxflow_batch(probs, bucket="max", compact=True,
                                   mesh=make_solver_mesh(s))
        for a, b in zip(comp, base):
            _assert_trees_equal(a, b)


@multi
def test_compact_lanes_validation():
    mesh = make_solver_mesh(2)
    with pytest.raises(ValueError, match="not divisible"):
        compact_lanes(mesh, None, 5)
    lanes = compact_lanes(mesh, None, 6)
    assert [(lo, hi) for lo, hi, _ in lanes] == [(0, 3), (3, 6)]
    assert [d for _, _, d in lanes] == list(mesh.devices.reshape(-1))
    probs = _ragged_grid_problems(6, 3, 6, 6)
    with pytest.raises(ValueError, match="not divisible"):
        maxflow_grid_batch(stack_grid_problems(probs), compact=True,
                           mesh=mesh)


def test_engine_compact_matches_direct_front_end():
    """A compact engine returns exactly what the direct batch calls do
    (sharded when >1 device is available)."""
    mesh = make_solver_mesh() if N_DEV >= 2 else None
    engine = SolverEngine(mesh=mesh, bucket="max", compact=True)
    rng = np.random.default_rng(7)
    probs = [GridProblem(*map(jnp.asarray, random_grid_problem(rng, h, w)))
             for h, w in [(6, 6), (4, 5), (6, 6)]]
    ws = [rng.integers(0, 50, (n, n)) for n in (5, 7)]
    tickets = [engine.submit("maxflow", p) for p in probs]
    tickets += [engine.submit("assignment", w) for w in ws]
    out = engine.flush()
    assert sorted(out) == tickets and engine.pending() == 0

    base_f = solve_maxflow_batch(probs, bucket="max", mesh=mesh)
    base_a = solve_assignment_batch(ws, bucket="max", mesh=mesh)
    for t, b in zip(tickets, base_f + base_a):
        _assert_trees_equal(out[t], b)
