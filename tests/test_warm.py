"""Incremental re-solve: warm-start seams, graph deltas, solution cache.

The warm seam's CORRECTNESS CONTRACT (docs/warmstart.md): a warm solve of
a mutated problem reaches exactly the optimum a cold solve of the same
mutated problem reaches —

* maxflow: the warm flow VALUE bit-matches the cold one, and the warm
  trajectory never violates the push-relabel height invariant
  (``check_no_violations``);
* assignment: warm re-enters the ε-scaling ladder with the cached prices
  and lands on the same optimal weight;
* matching: the surviving matched pairs seed the augmenting rounds and
  warm cardinality equals Hopcroft–Karp's;

and the seam is DRIVER-INDEPENDENT: masked, compacted, refill, and
mesh-sharded dispatches of the same warm batch agree (the per-instance
init is the only thing warm changes — the loop drivers are untouched).

Random delta sequences chain solves (each step warm-starts from the
previous solution) so staleness compounds the way a serving stream would
compound it; hypothesis widens the delta space when installed and the
fixed-seed sweep stands in when it is not.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.batch import GridProblem, solve_batch
from repro.core.kinds import get_kind
from repro.core.maxflow.grid import check_no_violations
from repro.core.maxflow.ref import maxflow_grid_ref, random_grid_problem
from repro.core.assignment.ref import optimal_weight
from repro.core.matching.ref import hopcroft_karp, random_bipartite
from repro.core.refill import RefillSolver
from repro.core.warm import (GraphDelta, SolutionCache, WarmStart,
                             apply_delta, content_key, delta_bound,
                             solve_warm)

pytestmark = pytest.mark.warm

N_DEV = len(jax.devices())
FORCE_FLAG = "--xla_force_host_platform_device_count=8"
multi = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices; covered via the subprocess test")


def _grid(rng, H=6, W=7):
    return GridProblem(*map(jnp.asarray, random_grid_problem(rng, H, W)))


def _mf_ref(p) -> int:
    return maxflow_grid_ref(np.asarray(p.cap_nbr), np.asarray(p.cap_src),
                            np.asarray(p.cap_sink))


def _mutate_grid(rng, p, n_edits=4) -> GridProblem:
    """Random capacity delta: bump interior arcs up/down, scale terminals."""
    cap = np.asarray(p.cap_nbr).copy()
    H, W = cap.shape[-2:]
    for _ in range(n_edits):
        d, y, x = rng.integers(4), rng.integers(H), rng.integers(W)
        if cap[d, y, x] > 0:      # keep off-grid arcs at zero (well-formed)
            cap[d, y, x] = max(0.0, cap[d, y, x] + rng.integers(-4, 5))
    ct = np.maximum(np.asarray(p.cap_sink)
                    + rng.integers(-2, 3, (H, W)), 0.0)
    return GridProblem(jnp.asarray(cap, jnp.float32), p.cap_src,
                       jnp.asarray(ct, jnp.float32))


def _mutate_w(rng, w, n_edits=3):
    w2 = np.asarray(w).copy()
    n = w2.shape[0]
    for _ in range(n_edits):
        i, j = rng.integers(n), rng.integers(n)
        w2[i, j] = max(0, w2[i, j] + rng.integers(-3, 4))
    return w2


def _mutate_adj(rng, adj, n_edits=4):
    a = np.asarray(adj).copy()
    nl, nr = a.shape
    for _ in range(n_edits):
        a[rng.integers(nl), rng.integers(nr)] ^= True
    return a


# ------------------------------------------------- per-kind equivalence


def test_maxflow_warm_equals_cold_over_delta_sequence():
    """Chained deltas: each step warm-starts from the previous solution and
    must bit-match the cold flow of its own mutated graph."""
    rng = np.random.default_rng(0)
    kind = get_kind("maxflow")
    p = _grid(rng)
    sol, base = None, None
    for step in range(5):
        if step:
            p = _mutate_grid(rng, base)
        warm = {0: WarmStart(sol, base_problem=base)} if sol else None
        res = (solve_warm("maxflow", [p], warm)[0] if warm
               else solve_batch("maxflow", [p])[0])
        cold = solve_batch("maxflow", [p])[0]
        ref = _mf_ref(p)
        assert float(res.flow) == float(cold.flow), step
        assert abs(float(res.flow) - ref) < 1e-4, step
        assert bool(check_no_violations(res.state)), step
        sol, base = kind.solution_of(res), p


def test_assignment_warm_equals_cold_over_delta_sequence():
    rng = np.random.default_rng(1)
    kind = get_kind("assignment")
    w = rng.integers(0, 20, (6, 6)).astype(np.int32)
    sol, base = None, None
    for step in range(5):
        if step:
            w = _mutate_w(rng, base)
        warm = {0: WarmStart(sol, base_problem=base)} if sol else None
        res = (solve_warm("assignment", [w], warm)[0] if warm
               else solve_batch("assignment", [w])[0])
        assert int(res.weight) == optimal_weight(w), step
        assert bool(res.converged), step
        sol, base = kind.solution_of(res), w


def test_matching_warm_equals_cold_over_delta_sequence():
    rng = np.random.default_rng(2)
    kind = get_kind("matching")
    adj = random_bipartite(rng, 8, 7, p=0.3)
    sol, base = None, None
    for step in range(5):
        if step:
            adj = _mutate_adj(rng, base)
        warm = {0: WarmStart(sol, base_problem=base)} if sol else None
        res = (solve_warm("matching", [adj], warm)[0] if warm
               else solve_batch("matching", [adj])[0])
        assert int(res.cardinality) == hopcroft_karp(adj)[2], step
        mr = np.asarray(res.match_row)
        matched = mr >= 0
        # the warm result is a VALID matching of the mutated graph
        assert np.asarray(adj)[matched, mr[matched]].all(), step
        assert len(set(mr[matched])) == matched.sum(), step
        sol, base = kind.solution_of(res), adj


def test_warm_without_base_problem_still_correct():
    """No base_problem (unknown provenance): maxflow falls back to a cold
    per-instance init, assignment uses the conservative eps ladder —
    correctness must hold either way."""
    rng = np.random.default_rng(3)
    p = _grid(rng)
    sol = get_kind("maxflow").solution_of(solve_batch("maxflow", [p])[0])
    p2 = _mutate_grid(rng, p)
    res = solve_warm("maxflow", [p2], {0: WarmStart(sol)})[0]
    assert abs(float(res.flow) - _mf_ref(p2)) < 1e-4

    w = rng.integers(0, 15, (5, 5)).astype(np.int32)
    sol = get_kind("assignment").solution_of(
        solve_batch("assignment", [w])[0])
    w2 = _mutate_w(rng, w)
    res = solve_warm("assignment", [w2], {0: WarmStart(sol)})[0]
    assert int(res.weight) == optimal_weight(w2)


# ------------------------------------------------- drivers agree


def test_masked_compacted_refill_agree_on_warm_batch():
    rng = np.random.default_rng(4)
    kind = get_kind("maxflow")
    bases = [_grid(rng) for _ in range(4)]
    sols = [kind.solution_of(r) for r in solve_batch("maxflow", bases)]
    mutated = [_mutate_grid(rng, b) for b in bases]
    warm = {i: WarmStart(sols[i], base_problem=bases[i])
            for i in (0, 2)}                       # mixed warm/cold batch
    masked = solve_warm("maxflow", mutated, warm)
    compacted = solve_warm("maxflow", mutated, warm, compact=True)
    s = RefillSolver("maxflow", shape=(6, 7), capacity=4)
    refill = s.run(mutated, warm=warm)
    for i, (m, c) in enumerate(zip(masked, compacted)):
        ref = _mf_ref(mutated[i])
        assert abs(float(m.flow) - ref) < 1e-4, i
        assert float(m.flow) == float(c.flow) == float(refill[i].flow), i
        assert int(m.rounds) == int(c.rounds) == int(refill[i].rounds), i


def test_refill_admits_warm_pairs_mid_solve():
    rng = np.random.default_rng(5)
    kind = get_kind("maxflow")
    base = _grid(rng)
    sol = kind.solution_of(solve_batch("maxflow", [base])[0])
    p2 = _mutate_grid(rng, base)
    fed = {"done": False}

    def admit(n_free):
        if fed["done"]:
            return []
        fed["done"] = True
        return [(p2, WarmStart(sol, base_problem=base))]

    s = RefillSolver("maxflow", shape=(6, 7), capacity=2)
    out = s.run([_grid(rng)], admit=admit)
    assert abs(float(out[1].flow) - _mf_ref(p2)) < 1e-4


@multi
def test_sharded_warm_matches_unsharded():
    from repro.launch.mesh import make_solver_mesh
    rng = np.random.default_rng(6)
    kind = get_kind("maxflow")
    bases = [_grid(rng, 5, 5) for _ in range(4)]
    sols = [kind.solution_of(r) for r in solve_batch("maxflow", bases)]
    mutated = [_mutate_grid(rng, b) for b in bases]
    warm = {i: WarmStart(sols[i], base_problem=bases[i]) for i in range(4)}
    plain = solve_warm("maxflow", mutated, warm)
    for n in sorted({2, N_DEV}):
        mesh = make_solver_mesh(n)
        sharded = solve_warm("maxflow", mutated, warm, mesh=mesh)
        for i, (a, b) in enumerate(zip(plain, sharded)):
            assert float(a.flow) == float(b.flow), (n, i)
            assert int(a.rounds) == int(b.rounds), (n, i)


# ------------------------------------------------- delta + cache units


def test_graph_delta_field_and_dense_forms():
    rng = np.random.default_rng(7)
    p = _grid(rng)
    d = GraphDelta(idx=(np.array([3]), np.array([2]), np.array([2])),
                   values=np.array([9.0], np.float32), field="cap_nbr")
    p2 = apply_delta("maxflow", p, d)
    assert float(np.asarray(p2.cap_nbr)[3, 2, 2]) == 9.0
    # original payload is never aliased
    assert float(np.asarray(p.cap_nbr)[3, 2, 2]) != 9.0 or True
    w = rng.integers(0, 9, (4, 4)).astype(np.int32)
    d = GraphDelta(idx=(np.array([1]), np.array([2])),
                   values=np.array([7], np.int32))
    w2 = apply_delta("assignment", w, d)
    assert w2[1, 2] == 7 and np.asarray(w)[1, 2] == w[1, 2]
    # a delta sequence applies in order
    seq = [GraphDelta(idx=(np.array([0]), np.array([0])),
                      values=np.array([5], np.int32)),
           GraphDelta(idx=(np.array([0]), np.array([0])),
                      values=np.array([3], np.int32))]
    assert apply_delta("assignment", w, seq)[0, 0] == 3
    with pytest.raises(ValueError, match="field"):
        apply_delta("maxflow", p, GraphDelta(
            idx=(np.array([0]),), values=np.array([1.0]), field="nope"))


def test_delta_bound_and_content_key():
    rng = np.random.default_rng(8)
    w = rng.integers(0, 9, (4, 4)).astype(np.int32)
    w2 = w.copy()
    w2[2, 2] += 5
    assert delta_bound(w2, w) == 5.0
    assert delta_bound(w, w) == 0.0
    k1, k2 = content_key("assignment", w), content_key("assignment", w2)
    assert k1 != k2 and k1 == content_key("assignment", w.copy())
    # kind participates in the key
    adj = np.zeros((4, 4), bool)
    assert content_key("matching", adj) != content_key(
        "matching", np.zeros((4, 5), bool))


def test_solution_cache_lru_and_budgets():
    rng = np.random.default_rng(9)
    cache = SolutionCache(max_entries=2)
    ws = [rng.integers(0, 9, (4, 4)).astype(np.int32) for _ in range(3)]
    keys = [cache.put("assignment", w, {"p_y": np.zeros(4, np.int32)})
            for w in ws]
    assert len(cache) == 2
    assert cache.get(keys[0]) is None          # LRU'd out (no spill dir)
    assert cache.get(keys[2]) is not None
    st_ = cache.stats()
    assert st_["hits"] == 1 and st_["misses"] == 1
    # byte budget: sole entry is never evicted
    tiny = SolutionCache(max_entries=8, max_bytes=1)
    k = tiny.put("assignment", ws[0], {"p_y": np.zeros(4, np.int32)})
    assert tiny.get(k) is not None


def test_solution_cache_spills_and_reloads(tmp_path):
    rng = np.random.default_rng(10)
    cache = SolutionCache(max_entries=1, spill_dir=str(tmp_path))
    w0 = rng.integers(0, 9, (4, 4)).astype(np.int32)
    w1 = rng.integers(0, 9, (4, 4)).astype(np.int32)
    k0 = cache.put("assignment", w0, {"p_y": np.arange(4, dtype=np.int32)})
    cache.put("assignment", w1, {"p_y": np.zeros(4, np.int32)})
    assert cache.stats()["spilled"] == 1       # k0 spilled to disk
    assert any(d.startswith("kv_") for d in os.listdir(tmp_path))
    hit = cache.get(k0)                        # transparently reloaded
    assert hit is not None
    np.testing.assert_array_equal(np.asarray(hit.solution["p_y"]),
                                  np.arange(4))
    # and the reloaded solution still warm-starts correctly
    w2 = _mutate_w(rng, w0)
    res = solve_warm("assignment", [w2],
                     {0: WarmStart(hit.solution, base_problem=hit.problem)})
    assert int(res[0].weight) == optimal_weight(w2)


# ------------------------------------------------- serving seam


def test_engine_submit_base_delta_and_metrics():
    from repro.serve.engine import SolverEngine
    from repro.serve.metrics import SchedulerMetrics
    rng = np.random.default_rng(11)
    p = _grid(rng, 5, 5)
    m = SchedulerMetrics()
    eng = SolverEngine(metrics=m)
    t1 = eng.submit("maxflow", p)
    eng.flush()
    d = GraphDelta(idx=(np.array([3]), np.array([2]), np.array([2])),
                   values=np.array([9.0], np.float32), field="cap_nbr")
    t2 = eng.submit("maxflow", base=t1, delta=d)
    r2 = eng.flush()[t2]
    assert abs(float(r2.flow) - _mf_ref(apply_delta("maxflow", p, d))) < 1e-4
    snap = m.snapshot()["warm"]
    assert snap["cache_hits"] == 1 and snap["warm_solves"] == 1
    assert snap["warm_fraction"] == 0.5        # one warm, one cold so far
    # base by cache key; unknown base raises KeyError (caller retries cold)
    key = eng.cache.key("maxflow", p)
    t3 = eng.submit("maxflow", base=key, delta=d)
    assert float(eng.flush()[t3].flow) == float(r2.flow)
    with pytest.raises(KeyError):
        eng.submit("maxflow", base=10_000, delta=d)
    with pytest.raises(ValueError, match="base="):
        eng.submit("maxflow", delta=d)


@pytest.mark.serve
def test_scheduler_submit_base_delta_warm_path():
    from repro.serve.scheduler import AsyncSolverEngine
    rng = np.random.default_rng(12)
    p = _grid(rng, 5, 5)
    d = GraphDelta(idx=(np.array([3]), np.array([2]), np.array([2])),
                   values=np.array([9.0], np.float32), field="cap_nbr")
    p2 = apply_delta("maxflow", p, d)
    with AsyncSolverEngine(max_batch=4, max_delay_ms=10.0) as eng:
        f1 = eng.submit("maxflow", p)
        r1 = f1.result(timeout=120)
        assert abs(float(r1.flow) - _mf_ref(p)) < 1e-4
        f2 = eng.submit("maxflow", base=0, delta=d)
        r2 = f2.result(timeout=120)
        assert abs(float(r2.flow) - _mf_ref(p2)) < 1e-4
        snap = eng.metrics.snapshot()["warm"]
        assert snap["cache_hits"] >= 1 and snap["warm_solves"] >= 1
    # same stream through the continuous-batching route
    with AsyncSolverEngine(max_batch=2, max_delay_ms=10.0,
                           refill=True) as eng:
        eng.submit("maxflow", p).result(timeout=120)
        r2 = eng.submit("maxflow", base=0, delta=d).result(timeout=120)
        assert abs(float(r2.flow) - _mf_ref(p2)) < 1e-4


# ------------------------------------------------- property suite


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n_edits=st.integers(1, 8))
def test_property_maxflow_warm_equivalence(seed, n_edits):
    rng = np.random.default_rng(seed)
    kind = get_kind("maxflow")
    p = _grid(rng, 5, 6)
    res = solve_batch("maxflow", [p])[0]
    p2 = _mutate_grid(rng, p, n_edits=n_edits)
    warm = solve_warm("maxflow", [p2],
                      {0: WarmStart(kind.solution_of(res),
                                    base_problem=p)})[0]
    assert abs(float(warm.flow) - _mf_ref(p2)) < 1e-4
    assert bool(check_no_violations(warm.state))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n_edits=st.integers(1, 6))
def test_property_assignment_warm_equivalence(seed, n_edits):
    rng = np.random.default_rng(seed)
    kind = get_kind("assignment")
    w = rng.integers(0, 25, (5, 5)).astype(np.int32)
    res = solve_batch("assignment", [w])[0]
    w2 = _mutate_w(rng, w, n_edits=n_edits)
    warm = solve_warm("assignment", [w2],
                      {0: WarmStart(kind.solution_of(res),
                                    base_problem=w)})[0]
    assert int(warm.weight) == optimal_weight(w2)


@pytest.mark.skipif(HAVE_HYPOTHESIS, reason="hypothesis covers this wider")
def test_fixed_seed_warm_equivalence_sweep():
    """Offline fallback for the property suite: a deterministic seed sweep
    over the same delta space."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        kind = get_kind("maxflow")
        p = _grid(rng, 5, 6)
        res = solve_batch("maxflow", [p])[0]
        p2 = _mutate_grid(rng, p, n_edits=1 + seed % 8)
        warm = solve_warm("maxflow", [p2],
                          {0: WarmStart(kind.solution_of(res),
                                        base_problem=p)})[0]
        assert abs(float(warm.flow) - _mf_ref(p2)) < 1e-4


# ------------------------------------------------- multi-device relaunch


@pytest.mark.slow  # fresh 8-device process re-runs this whole file
@pytest.mark.skipif(N_DEV >= 2, reason="already multi-device")
def test_forced_multi_device_subprocess():
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", str(__file__),
         "-m", "not slow"],
        cwd=root, env=env, capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, f"\n--- stdout ---\n{r.stdout}\n{r.stderr}"
    assert "passed" in r.stdout
