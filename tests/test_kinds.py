"""Solver-kind registry: error paths, registration rules, deprecation shims.

The contracts under test (repro.core.kinds + the serve layer's shims):

* unknown kinds raise ``ValueError`` NAMING the registered kinds — from
  ``get_kind`` and from every front end that dispatches through it;
* duplicate registration raises (silent overwrite would make dispatch
  order-of-import dependent), as do malformed kind names;
* ``registered_kinds()`` ensures the builtins and preserves registration
  order; ``ensure=False`` peeks without importing solver modules;
* the pre-registry serve spellings — ``SolverEngine(maxflow_kw=,
  assignment_kw=)``, ``submit_maxflow`` / ``submit_assignment`` on both
  engines — still work but emit ``DeprecationWarning`` and delegate to the
  generic ``solver_kw`` / ``submit(kind, ...)`` path.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.kinds as kinds_mod
from repro.core.batch import solve_batch
from repro.core.kinds import (SolverKind, get_kind, register_kind,
                              registered_kinds)
from repro.core.maxflow.grid import GridProblem
from repro.core.maxflow.ref import random_grid_problem
from repro.serve.engine import SolverEngine


def _prob(rng, h=5, w=5):
    return GridProblem(*map(jnp.asarray, random_grid_problem(rng, h, w)))


def _dummy_kind(name):
    f = lambda *a, **k: None  # noqa: E731
    return SolverKind(name=name, validate=f, inert_problem=f,
                      prepare_buckets=f, solve_prepared=f, loop_spec=f)


# ------------------------------------------------------------ error paths

def test_unknown_kind_names_registered_kinds():
    with pytest.raises(ValueError) as ei:
        get_kind("tsp")
    msg = str(ei.value)
    assert "unknown solver kind 'tsp'" in msg
    for name in ("maxflow", "assignment", "matching"):
        assert name in msg


def test_unknown_kind_raises_from_every_front_end():
    with pytest.raises(ValueError, match="registered kinds"):
        solve_batch("tsp", [object()])
    with pytest.raises(ValueError, match="registered kinds"):
        SolverEngine().submit("tsp", object())
    from repro.core.batch import prepare_buckets
    with pytest.raises(ValueError, match="registered kinds"):
        prepare_buckets("tsp", [object()])


def test_duplicate_registration_raises(monkeypatch):
    registered_kinds()                     # ensure builtins are present
    with pytest.raises(ValueError, match="already registered"):
        register_kind(_dummy_kind("matching"))
    # and a scratch name registers exactly once
    monkeypatch.delitem(kinds_mod._REGISTRY, "scratch", raising=False)
    register_kind(_dummy_kind("scratch"))
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_kind(_dummy_kind("scratch"))
        assert "scratch" in registered_kinds()
    finally:
        del kinds_mod._REGISTRY["scratch"]


def test_malformed_kind_name_raises():
    with pytest.raises(ValueError, match="non-empty string"):
        register_kind(_dummy_kind(""))
    with pytest.raises(ValueError, match="non-empty string"):
        register_kind(_dummy_kind(None))


def test_registered_kinds_order_and_peek():
    ks = registered_kinds()
    assert ks.index("maxflow") < ks.index("assignment") < ks.index(
        "matching")
    # peek mode never shrinks the view once the builtins are in
    assert set(registered_kinds(ensure=False)) == set(ks)
    assert get_kind("maxflow").name == "maxflow"


# ------------------------------------------------------ deprecation shims

def test_engine_deprecated_solver_kwargs_map_to_solver_kw():
    with pytest.warns(DeprecationWarning, match="maxflow_kw"):
        eng = SolverEngine(maxflow_kw={"backend": "xla"})
    assert eng.solver_kw == {"maxflow": {"backend": "xla"}}
    with pytest.warns(DeprecationWarning, match="assignment_kw"):
        eng = SolverEngine(solver_kw={"matching": {"max_rounds": 5}},
                           assignment_kw={"alpha": 4})
    assert eng.solver_kw == {"matching": {"max_rounds": 5},
                             "assignment": {"alpha": 4}}


def test_engine_deprecated_submit_shims_delegate():
    rng = np.random.default_rng(0)
    eng = SolverEngine()
    with pytest.warns(DeprecationWarning, match="submit_maxflow"):
        t0 = eng.submit_maxflow(_prob(rng))
    with pytest.warns(DeprecationWarning, match="submit_assignment"):
        t1 = eng.submit_assignment(rng.integers(0, 9, (4, 4)))
    out = eng.flush()
    assert sorted(out) == [t0, t1]
    assert bool(out[t0].converged) and bool(out[t1].converged)
    # the shims still validate (delegation, not a bypass)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="malformed assignment"):
            eng.submit_assignment(np.ones((3, 4)))


@pytest.mark.serve
def test_async_engine_deprecated_shims_delegate():
    from repro.serve.scheduler import AsyncSolverEngine
    rng = np.random.default_rng(1)
    with pytest.warns(DeprecationWarning, match="maxflow_kw"):
        eng = AsyncSolverEngine(max_batch=2, max_delay_ms=600_000.0,
                                maxflow_kw={"backend": "xla"})
    with eng:
        with pytest.warns(DeprecationWarning, match="submit_maxflow"):
            f0 = eng.submit_maxflow(_prob(rng))
        with pytest.warns(DeprecationWarning, match="submit_assignment"):
            f1 = eng.submit_assignment(rng.integers(0, 9, (4, 4)))
        eng.flush_now()
        assert bool(f0.result(timeout=120.0).converged)
        assert bool(f1.result(timeout=120.0).converged)
