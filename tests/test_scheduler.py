"""Async serving scheduler: futures bit-match the blocking path, triggers
fire without manual flushes, shutdown never hangs, poison stays isolated.

The contract under test (repro.serve.scheduler.AsyncSolverEngine):

* BIT-MATCH — the scheduler decides WHEN and WHERE the tested batch path
  runs, never what it computes: for a recorded request stream, async
  futures == synchronous ``SolverEngine.flush()`` of the same chunks ==
  a loop of single solves. Checked on the plain, sharded (2 and the full
  emulated device count), and compacted paths.
* TRIGGERS — a kind flushes when ``max_batch`` requests are queued (size)
  or a request's ``deadline_ms`` expires (deadline), with no manual
  flush; ``close(drain=True)`` resolves everything pending,
  ``close(drain=False)`` cancels queued futures. Neither hangs.
* ADAPTIVE DISPATCH — per-bucket masked-vs-compacted choice follows the
  convergence-spread EWMA (ragged streams go compacted, uniform streams
  stay masked), with ``dispatch=`` as the forced override.
* ISOLATION — a request that makes the batched dispatch raise fails only
  its own future; batch-mates still get results.

Timing discipline: these tests are THREADED — every wait uses a generous
budget (the ``serve`` marker's contract, see pyproject.toml) and asserts
on events, never on sleeps. Multi-device is emulated exactly as in
test_shard.py: a slow subprocess test relaunches this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; CI also runs the
file directly with the flag exported.
"""
import os
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.kinds as kinds_mod
from repro.core.assignment.cost_scaling import solve_assignment
from repro.core.batch import solve_maxflow_batch
from repro.core.maxflow.grid import GridProblem, maxflow_grid
from repro.core.maxflow.ref import random_grid_problem
from repro.core.solver_loop import trace_cycles
from repro.launch.mesh import make_solver_mesh, scheduler_lanes, shard_count
from repro.serve.engine import SolverEngine
from repro.serve.metrics import (ConvergenceStats, Ewma, LatencyWindow,
                                 SchedulerMetrics)
from repro.serve.scheduler import AsyncSolverEngine, choose_driver

pytestmark = pytest.mark.serve

N_DEV = len(jax.devices())
FORCE_FLAG = "--xla_force_host_platform_device_count=8"
multi = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices; covered via the subprocess test")
SHARD_COUNTS = sorted({2, N_DEV}) if N_DEV >= 2 else []

# generous budgets: thread-timing tests must not flake on slow CI workers
WAIT_S = 120.0
LONG_DEADLINE_MS = 600_000.0


def _grid_problems(seed, B, H, W):
    rng = np.random.default_rng(seed)
    return [GridProblem(*map(jnp.asarray, random_grid_problem(rng, H, W)))
            for _ in range(B)]


def _ragged_grid_problems(seed, B, H, W):
    """Most instances converge in the first cycles, a few run long —
    the convergence-spread signal adaptive dispatch keys on."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(B):
        cap, cs, ct = random_grid_problem(rng, H, W)
        if i % 4:                        # 3 of every 4 are easy
            cs = np.minimum(cs, 1.0)
        out.append(GridProblem(*map(jnp.asarray, (cap, cs, ct))))
    return out


def _assert_trees_equal(a, b):
    for name, la, lb in zip(a._fields, a, b):
        if isinstance(la, tuple):  # nested NamedTuple (GridFlowState)
            _assert_trees_equal(la, lb)
        else:
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb), err_msg=name)


@pytest.mark.slow  # ~2 min: full scheduler suite in a fresh 8-dev process
@pytest.mark.skipif(N_DEV >= 2, reason="already multi-device")
def test_forced_multi_device_subprocess():
    """Relaunch this file under 8 emulated host devices and require green."""
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", str(__file__)],
        cwd=root, env=env, capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, f"\n--- stdout ---\n{r.stdout}\n{r.stderr}"
    assert "passed" in r.stdout


# ------------------------------------------------------------- bit-match

def _bitmatch_stream(async_kw: dict, sync_kw: dict, chunk: int = 4):
    """Submit a recorded stream both ways; futures must tree-equal the
    synchronous flush of the same chunks."""
    probs = _grid_problems(0, 2 * chunk, 8, 8)
    ws = [np.random.default_rng(i).integers(0, 50, (6, 6))
          for i in range(chunk)]
    with AsyncSolverEngine(max_batch=chunk,
                           max_delay_ms=LONG_DEADLINE_MS, **async_kw) as eng:
        f_futs = [eng.submit("maxflow", p) for p in probs]
        a_futs = [eng.submit("assignment", w) for w in ws]
        eng.flush_now()                  # the assignment chunk is short
        f_res = [f.result(timeout=WAIT_S) for f in f_futs]
        a_res = [f.result(timeout=WAIT_S) for f in a_futs]

    sync = SolverEngine(**sync_kw)
    base_f, base_a = [], []
    for lo in range(0, len(probs), chunk):
        ts = [sync.submit("maxflow", p) for p in probs[lo:lo + chunk]]
        out = sync.flush()
        base_f += [out[t] for t in ts]
    ts = [sync.submit("assignment", w) for w in ws]
    out = sync.flush()
    base_a += [out[t] for t in ts]

    for got, want in zip(f_res + a_res, base_f + base_a):
        _assert_trees_equal(got, want)
    return f_res, a_res, probs, ws


def test_async_bitmatch_plain_vs_sync_and_single():
    f_res, a_res, probs, ws = _bitmatch_stream({"dispatch": "masked"}, {})
    # ... and the loop-of-single-solves layer of the contract
    for got, p in zip(f_res, probs):
        single = maxflow_grid(p)
        assert float(got.flow) == float(single.flow)
        assert int(got.rounds) == int(single.rounds)
        np.testing.assert_array_equal(np.asarray(got.cut),
                                      np.asarray(single.cut))
    for got, w in zip(a_res, ws):
        single = solve_assignment(jnp.asarray(w))
        assert int(got.weight) == int(single.weight)
        np.testing.assert_array_equal(np.asarray(got.col_of_row),
                                      np.asarray(single.col_of_row))


def test_async_bitmatch_compacted():
    _bitmatch_stream({"dispatch": "compacted"}, {"compact": True})


@multi
def test_async_bitmatch_sharded():
    """Sharded scheduler (lanes on disjoint sub-meshes when the mesh is
    big enough) == sharded sync flush == the UNSHARDED sync flush."""
    for s in SHARD_COUNTS:
        _bitmatch_stream({"mesh": make_solver_mesh(s), "n_lanes": 2,
                          "dispatch": "masked"}, {})


def test_async_bitmatch_ragged_exact_bucket():
    """bucket="exact" means results are independent of batch composition
    entirely — async == single solves for a ragged shape mix."""
    rng = np.random.default_rng(3)
    shapes = [(5, 5), (8, 8), (4, 7), (8, 8), (5, 5), (4, 7)]
    probs = [GridProblem(*map(jnp.asarray, random_grid_problem(rng, h, w)))
             for h, w in shapes]
    with AsyncSolverEngine(max_batch=3, max_delay_ms=LONG_DEADLINE_MS,
                           bucket="exact", dispatch="masked") as eng:
        futs = [eng.submit("maxflow", p) for p in probs]
        res = [f.result(timeout=WAIT_S) for f in futs]
    for got, p in zip(res, probs):
        single = maxflow_grid(p)
        assert float(got.flow) == float(single.flow)
        assert int(got.rounds) == int(single.rounds)
        np.testing.assert_array_equal(np.asarray(got.cut),
                                      np.asarray(single.cut))


# ------------------------------------------------------------- triggers

def test_deadline_trigger_completes_without_flush():
    """A lone request (far below max_batch) completes inside its deadline
    budget with NO manual flush — the background thread did it."""
    [p] = _grid_problems(4, 1, 8, 8)
    with AsyncSolverEngine(max_batch=64, max_delay_ms=250.0) as eng:
        t0 = time.monotonic()
        fut = eng.submit("maxflow", p)
        res = fut.result(timeout=WAIT_S)
        elapsed = time.monotonic() - t0
        snap = eng.metrics.snapshot()
    assert bool(res.converged)
    assert snap["flushes_by_trigger"].get("deadline", 0) >= 1
    assert snap["flushes_by_trigger"].get("size", 0) == 0
    # generous sanity bound, not a tight latency assertion (serve marker)
    assert elapsed < WAIT_S


def test_size_trigger_fires_at_max_batch():
    probs = _grid_problems(5, 4, 8, 8)
    with AsyncSolverEngine(max_batch=4,
                           max_delay_ms=LONG_DEADLINE_MS) as eng:
        eng.flush_now()          # empty queue: must NOT arm a stale manual
        futs = [eng.submit("maxflow", p) for p in probs]
        res = [f.result(timeout=WAIT_S) for f in futs]
        snap = eng.metrics.snapshot()
    assert all(bool(r.converged) for r in res)
    # the batch flushed on SIZE — a stale manual flag would have dispatched
    # the first submission as a singleton 'manual' batch instead
    assert snap["flushes_by_trigger"].get("manual", 0) == 0
    assert snap["flushes_by_trigger"].get("size", 0) >= 1
    assert snap["tickets"]["completed"] == 4
    assert snap["latency_ms"]["p50"] is not None
    assert snap["latency_ms"]["p99"] >= snap["latency_ms"]["p50"]


def test_shutdown_drains_pending_futures():
    probs = _grid_problems(6, 3, 8, 8)
    eng = AsyncSolverEngine(max_batch=64, max_delay_ms=LONG_DEADLINE_MS)
    futs = [eng.submit("maxflow", p) for p in probs]
    eng.close(drain=True)                # must not hang, must resolve all
    for f in futs:
        assert bool(f.result(timeout=1.0).converged)
    assert eng.metrics.snapshot()["flushes_by_trigger"].get("drain", 0) >= 1
    eng.close()                          # idempotent


def test_shutdown_cancels_when_not_draining():
    probs = _grid_problems(7, 2, 8, 8)
    eng = AsyncSolverEngine(max_batch=64, max_delay_ms=LONG_DEADLINE_MS)
    futs = [eng.submit("maxflow", p) for p in probs]
    eng.close(drain=False)
    assert all(f.cancelled() for f in futs)
    assert eng.metrics.snapshot()["tickets"]["cancelled"] == 2
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit("maxflow", probs[0])


def test_submit_validates_before_future_exists():
    good = _grid_problems(8, 1, 6, 6)[0]
    bad = GridProblem(good.cap_nbr, -good.cap_src, good.cap_sink)
    with AsyncSolverEngine(max_batch=4, max_delay_ms=LONG_DEADLINE_MS) as eng:
        with pytest.raises(ValueError, match="negative"):
            eng.submit("maxflow", bad)
        with pytest.raises(ValueError, match="malformed assignment"):
            eng.submit("assignment", np.ones((3, 4)))
        assert eng.pending() == 0
        assert eng.metrics.snapshot()["tickets"].get("submitted", 0) == 0


# ------------------------------------------------------------- isolation

def test_poisoned_request_fails_only_its_own_future(monkeypatch):
    """A request that detonates the batched dispatch gets its exception;
    every batch-mate still resolves with a correct result."""
    POISON = 777

    real = kinds_mod.get_kind("assignment")

    def maybe_boom(prep, **kw):
        if any(int(np.asarray(o).ravel()[0]) == POISON
               for o in prep.originals):
            raise RuntimeError("poisoned dispatch")
        return real.solve_prepared(prep, **kw)

    monkeypatch.setitem(kinds_mod._REGISTRY, "assignment",
                        real._replace(solve_prepared=maybe_boom))

    rng = np.random.default_rng(9)
    ws = [rng.integers(0, 50, (5, 5)) for _ in range(3)]
    poisoned = ws[1].copy()
    poisoned.flat[0] = POISON
    stream = [ws[0], poisoned, ws[2]]
    with AsyncSolverEngine(max_batch=3, max_delay_ms=LONG_DEADLINE_MS) as eng:
        futs = [eng.submit("assignment", w) for w in stream]
        with pytest.raises(RuntimeError, match="poisoned"):
            futs[1].result(timeout=WAIT_S)
        for f, w in ((futs[0], ws[0]), (futs[2], ws[2])):
            got = f.result(timeout=WAIT_S)
            single = solve_assignment(jnp.asarray(w))
            assert int(got.weight) == int(single.weight)
        snap = eng.metrics.snapshot()
    assert snap["tickets"]["failed"] == 1
    assert snap["tickets"]["completed"] == 2


# ----------------------------------------------------- adaptive dispatch

def test_adaptive_dispatch_chooses_compaction_on_ragged_stream():
    """First chunk runs masked (no history); once the spread EWMA builds,
    ragged-convergence chunks flip to the compacted driver."""
    probs = _ragged_grid_problems(10, 12, 8, 8)
    with AsyncSolverEngine(max_batch=4, max_delay_ms=LONG_DEADLINE_MS,
                           dispatch="adaptive", spread_threshold=0.1,
                           min_compact_batch=2) as eng:
        for lo in range(0, len(probs), 4):
            futs = [eng.submit("maxflow", p) for p in probs[lo:lo + 4]]
            [f.result(timeout=WAIT_S) for f in futs]   # serialize chunks
        m = eng.metrics
        spread = m.convergence.spread("maxflow")
        masked = m.dispatch_count("maxflow", "masked")
        compacted = m.dispatch_count("maxflow", "compacted")
    assert spread is not None and spread > 0.1, \
        "stream not ragged — adaptive path untested"
    assert masked >= 1, "first dispatch (no history) should stay masked"
    assert compacted >= 1, "EWMA never flipped the driver to compacted"


def test_adaptive_dispatch_stays_masked_on_uniform_stream():
    # a truly uniform stream: the same instance repeated — identical
    # trajectories, zero round spread, so compaction never pays
    probs = _grid_problems(11, 1, 8, 8) * 8
    with AsyncSolverEngine(max_batch=4, max_delay_ms=LONG_DEADLINE_MS,
                           dispatch="adaptive", spread_threshold=0.1,
                           min_compact_batch=2) as eng:
        for lo in range(0, len(probs), 4):
            futs = [eng.submit("maxflow", p) for p in probs[lo:lo + 4]]
            [f.result(timeout=WAIT_S) for f in futs]
        assert eng.metrics.dispatch_count("maxflow", "compacted") == 0


def test_forced_dispatch_override():
    probs = _grid_problems(12, 4, 8, 8)
    with AsyncSolverEngine(max_batch=4, max_delay_ms=LONG_DEADLINE_MS,
                           dispatch="compacted") as eng:
        futs = [eng.submit("maxflow", p) for p in probs]
        [f.result(timeout=WAIT_S) for f in futs]
        assert eng.metrics.dispatch_count("maxflow", "masked") == 0
        assert eng.metrics.dispatch_count("maxflow", "compacted") >= 1
    with pytest.raises(ValueError, match="dispatch"):
        AsyncSolverEngine(dispatch="warp-speed")


def test_choose_driver_policy_table():
    kw = dict(threshold=0.25, min_batch=4)
    assert choose_driver(None, 8, forced="adaptive", **kw) is False
    assert choose_driver(0.1, 8, forced="adaptive", **kw) is False
    assert choose_driver(0.5, 8, forced="adaptive", **kw) is True
    assert choose_driver(0.5, 2, forced="adaptive", **kw) is False  # tiny
    assert choose_driver(0.5, 2, forced="compacted", **kw) is True
    assert choose_driver(0.9, 64, forced="masked", **kw) is False


# ------------------------------------------------- trace hook + metrics

def test_cycle_trace_hook_sees_live_set_shrink():
    """repro.core.solver_loop.trace_cycles: the compacted driver reports
    (cycle, n_live) per host cycle, and the live set only shrinks."""
    probs = _ragged_grid_problems(13, 6, 8, 8)
    calls: list[tuple[int, int]] = []
    with trace_cycles(lambda c, n: calls.append((c, n))):
        solve_maxflow_batch(probs, compact=True)
    assert calls, "compacted solve traced no cycles"
    assert calls[0] == (0, 6)
    lives = [n for _, n in calls]
    assert all(a >= b for a, b in zip(lives, lives[1:])), \
        f"live set grew: {lives}"
    # hook uninstalled outside the context
    calls.clear()
    solve_maxflow_batch(probs, compact=True)
    assert not calls


def test_metrics_primitives():
    e = Ewma(alpha=0.5)
    assert e.value is None
    assert e.update(1.0) == 1.0
    assert e.update(0.0) == 0.5
    with pytest.raises(ValueError):
        Ewma(alpha=0.0)

    w = LatencyWindow(maxlen=4)
    assert w.percentiles()["p50"] is None
    for x in (1.0, 2.0, 3.0, 4.0, 100.0):   # 1.0 evicted
        w.record(x)
    p = w.percentiles()
    assert p["p50"] == 3.5 and p["p99"] > 4.0 and len(w) == 4

    c = ConvergenceStats(alpha=1.0)
    assert c.spread("maxflow") is None
    c.observe("maxflow", spread=0.5, occupancy=0.75)
    assert c.spread("maxflow") == 0.5 and c.occupancy("maxflow") == 0.75

    m = SchedulerMetrics()
    m.record_submit(3)
    m.record_dispatch("maxflow", compact=True, spread=0.4, occupancy=0.5)
    m.record_live_trace(0, 8)
    m.record_live_trace(1, 4)
    snap = m.snapshot()
    assert snap["queue_depth"] == 3
    assert snap["dispatches"] == {"maxflow:compacted": 1}
    assert snap["compact_cycles"] == 2 and snap["compact_live_mean"] == 6.0


# ------------------------------------------------------ scheduler lanes

def test_scheduler_lanes_no_mesh():
    assert scheduler_lanes(None, None, 3) == [None, None, None]
    with pytest.raises(ValueError, match="n_lanes"):
        scheduler_lanes(None, None, 0)


def test_scheduler_lanes_single_device_shares_mesh():
    mesh = make_solver_mesh(1)
    lanes = scheduler_lanes(mesh, None, 2)
    assert len(lanes) == 2 and all(l is mesh for l in lanes)


@multi
def test_scheduler_lanes_split_devices_disjoint():
    mesh = make_solver_mesh()            # all devices
    lanes = scheduler_lanes(mesh, None, 2)
    assert len(lanes) == 2
    devs = [d for l in lanes for d in l.devices.reshape(-1)]
    assert len(devs) == N_DEV == len(set(devs)), "lanes overlap"
    assert sum(shard_count(l) for l in lanes) == N_DEV
