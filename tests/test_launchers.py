"""CLI launcher smoke tests (subprocess: the actual production entrypoints)."""
import os
import subprocess
import sys

import pytest

ENV = {**os.environ, "PYTHONPATH": "src"}
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=900):
    return subprocess.run([sys.executable, "-m", *args], cwd=ROOT, env=ENV,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_cli_with_resume(tmp_path):
    base = ["repro.launch.train", "--arch", "smollm-135m", "--smoke",
            "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "3", "--resume", "auto"]
    r1 = _run(base + ["--steps", "4"])
    assert r1.returncode == 0, r1.stderr[-2000:]
    assert "[ckpt]" in r1.stdout
    r2 = _run(base + ["--steps", "6"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[resume] restored step" in r2.stdout


@pytest.mark.slow
def test_serve_cli():
    r = _run(["repro.launch.serve", "--arch", "smollm-135m", "--smoke",
              "--batch", "2", "--prompt-len", "8", "--max-new", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "decode:" in r.stdout


@pytest.mark.slow
def test_dryrun_cli_single_cell():
    r = _run(["repro.launch.dryrun", "--arch", "smollm-135m", "--shape",
              "decode_32k"], timeout=1800)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[ok] smollm-135m/decode_32k" in r.stdout
