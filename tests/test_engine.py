"""SolverEngine edge cases: the synchronous serving core's contracts.

What's under test (repro.serve.engine):

* submit-time validation rejects malformed VALUES, not just shapes —
  negative / non-finite / non-numeric capacities never get a ticket
  (each kind's REGISTERED validator, ``repro.core.kinds``);
* ``flush()`` on an empty queue returns ``{}`` without dispatching;
* tickets stay globally ordered across interleaved submit/flush rounds
  and mixed kinds, and every flush returns exactly its round's tickets;
* partial-failure delivery: if one kind's batch raises, kinds that
  already completed are NOT re-solved on retry — their results are
  delivered by the next flush and only the failing kind stays queued;
* a submit landing WHILE a flush is solving is never dropped (it stays
  queued for the next round), and flush results iterate in ticket order.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.kinds as kinds_mod
from repro.core.kinds import get_kind
from repro.core.maxflow.grid import GridProblem
from repro.core.maxflow.ref import random_grid_problem
from repro.serve.engine import (SolverEngine, validate_assignment_matrix,
                                validate_grid_problem)


def _prob(rng, h=6, w=6):
    return GridProblem(*map(jnp.asarray, random_grid_problem(rng, h, w)))


# ---------------------------------------------------------- validation

def test_submit_rejects_bad_values_before_ticket():
    engine = SolverEngine()
    good = _prob(np.random.default_rng(0))
    neg = GridProblem(good.cap_nbr, -good.cap_src, good.cap_sink)
    with pytest.raises(ValueError, match="negative"):
        engine.submit("maxflow", neg)
    nan = GridProblem(good.cap_nbr,
                      jnp.full_like(good.cap_src, jnp.nan), good.cap_sink)
    with pytest.raises(ValueError, match="non-finite"):
        engine.submit("maxflow", nan)
    boolean = GridProblem(jnp.zeros((4, 6, 6), jnp.bool_),
                          good.cap_src, good.cap_sink)
    with pytest.raises(ValueError, match="non-numeric"):
        engine.submit("maxflow", boolean)
    # the reject-before-ticket contract: nothing was queued, and the next
    # good submit gets ticket 0 (no ticket was burned on a rejection)
    assert engine.pending() == 0
    assert engine.submit("maxflow", good) == 0


def test_submit_unknown_kind_names_registered_ones():
    engine = SolverEngine()
    with pytest.raises(ValueError, match="registered kinds.*maxflow"):
        engine.submit("tsp", object())
    assert engine.pending() == 0


def test_validators_canonicalize_good_requests():
    rng = np.random.default_rng(1)
    p = validate_grid_problem(_prob(rng))
    assert isinstance(p, GridProblem)
    # integer capacities are fine (float sums over them stay exact)
    ints = GridProblem(jnp.ones((4, 3, 3), jnp.int32),
                       jnp.ones((3, 3), jnp.int32),
                       jnp.ones((3, 3), jnp.int32))
    validate_grid_problem(ints)
    w = validate_assignment_matrix([[1, 2], [3, 4]])
    assert w.shape == (2, 2) and np.issubdtype(w.dtype, np.integer)
    with pytest.raises(ValueError, match="malformed assignment"):
        validate_assignment_matrix(np.ones((2, 2)))          # float


# ---------------------------------------------------------- empty / mixed

def test_flush_empty_queue_returns_empty_dict():
    engine = SolverEngine()
    assert engine.flush() == {}
    assert engine.flush() == {}          # idempotent, still no dispatch


def test_mixed_kind_queue_with_one_kind_empty():
    rng = np.random.default_rng(2)
    engine = SolverEngine()
    t0 = engine.submit("maxflow", _prob(rng))
    out = engine.flush()                 # assignment queue empty
    assert sorted(out) == [t0] and bool(out[t0].converged)

    t1 = engine.submit("assignment", rng.integers(0, 9, (4, 4)))
    out = engine.flush()                 # maxflow queue empty
    assert sorted(out) == [t1] and bool(out[t1].converged)


def test_ticket_ordering_across_interleaved_rounds():
    """Tickets are globally monotonic across kinds AND flush rounds, and
    each flush returns exactly the tickets submitted since the last one."""
    rng = np.random.default_rng(3)
    engine = SolverEngine()
    seen: list[int] = []
    for _ in range(3):
        round_tickets = [
            engine.submit("maxflow", _prob(rng)),
            engine.submit("assignment", rng.integers(0, 9, (4, 4))),
            engine.submit("matching", rng.random((4, 5)) < 0.5)]
        assert round_tickets == sorted(round_tickets)
        assert seen == [] or min(round_tickets) > max(seen)
        out = engine.flush()
        assert sorted(out) == round_tickets
        seen += round_tickets
    assert seen == list(range(9))


# ---------------------------------------------------------- partial failure

def test_completed_kind_delivers_when_other_kind_fails(monkeypatch):
    """The flush-order bugfix: max-flow solves first; if the assignment
    batch then raises, the max-flow results must survive — delivered by
    the retry flush WITHOUT re-solving — and only assignment stays queued.

    The failure is injected through the REGISTRY (the only dispatch seam
    the engine uses now)."""
    rng = np.random.default_rng(4)
    engine = SolverEngine()
    tf = engine.submit("maxflow", _prob(rng))
    ta = engine.submit("assignment", rng.integers(0, 9, (5, 5)))

    maxflow_calls = []
    real_maxflow = get_kind("maxflow")
    real_assignment = get_kind("assignment")

    def counting_maxflow(prep, **kw):
        maxflow_calls.append(prep)
        return real_maxflow.solve_prepared(prep, **kw)

    def assignment_boom(prep, **kw):
        raise RuntimeError("transient assignment failure")

    monkeypatch.setitem(kinds_mod._REGISTRY, "maxflow",
                        real_maxflow._replace(solve_prepared=counting_maxflow))
    monkeypatch.setitem(kinds_mod._REGISTRY, "assignment",
                        real_assignment._replace(
                            solve_prepared=assignment_boom))

    with pytest.raises(RuntimeError, match="transient"):
        engine.flush()
    # max-flow completed and left the queue; assignment stayed for retry
    assert engine.pending() == 1 and len(maxflow_calls) == 1

    monkeypatch.setitem(kinds_mod._REGISTRY, "assignment", real_assignment)
    out = engine.flush()
    # both tickets delivered; the max-flow batch was NOT re-solved
    assert sorted(out) == [tf, ta] and len(maxflow_calls) == 1
    assert bool(out[tf].converged) and bool(out[ta].converged)


def test_submit_during_flush_is_never_dropped(monkeypatch):
    """Regression: ``flush`` used to snapshot the queue and then
    ``clear()`` it — a submit landing WHILE the batch solved (from a
    callback or another thread) was silently discarded.  Now a mid-flush
    submit stays queued for the next flush, and each flush returns a
    ticket-ordered dict of exactly its own round."""
    rng = np.random.default_rng(6)
    engine = SolverEngine()
    late: list[int] = []

    real = get_kind("maxflow")

    def submitting_solve(prep, **kw):
        if not late:                     # re-entrant submit, mid-flush
            late.append(engine.submit("maxflow", _prob(rng)))
        return real.solve_prepared(prep, **kw)

    monkeypatch.setitem(kinds_mod._REGISTRY, "maxflow",
                        real._replace(solve_prepared=submitting_solve))

    t0 = engine.submit("maxflow", _prob(rng))
    out = engine.flush()
    # this round delivered only its own ticket...
    assert sorted(out) == [t0]
    # ...and the mid-flush submission survived for the next round
    assert engine.pending() == 1
    out2 = engine.flush()
    assert sorted(out2) == late
    assert bool(out2[late[0]].converged)


def test_flush_returns_ticket_ordered_dict():
    """Iteration order of a flush result is global ticket order even when
    kinds were submitted interleaved (kinds solve grouped, not in ticket
    order)."""
    rng = np.random.default_rng(7)
    engine = SolverEngine()
    tickets = [engine.submit("maxflow", _prob(rng)),
               engine.submit("assignment", rng.integers(0, 9, (4, 4))),
               engine.submit("maxflow", _prob(rng)),
               engine.submit("matching", rng.random((4, 5)) < 0.5)]
    out = engine.flush()
    assert list(out) == sorted(tickets)


def test_flush_stats_out_reports_buckets():
    rng = np.random.default_rng(5)
    engine = SolverEngine()
    engine.submit("maxflow", _prob(rng))
    engine.submit("maxflow", _prob(rng))
    engine.submit("assignment", rng.integers(0, 9, (4, 4)))
    engine.submit("matching", rng.random((5, 5)) < 0.4)
    stats = []
    out = engine.flush(stats_out=stats)
    assert len(out) == 4 and len(stats) == 3
    kinds = {s.kind: s for s in stats}
    assert kinds["maxflow"].n_real == 2
    assert kinds["assignment"].n_real == 1
    assert kinds["matching"].n_real == 1
    assert all(0.0 <= s.spread <= 1.0 for s in stats)
