"""Continuous batching: refill sessions bit-match closed batches, admission
is lossless, and the serving layer resolves futures per instance.

The contract under test (repro.core.refill + repro.serve.scheduler):

* BIT-MATCH — a refilled compacted session delivers, for EVERY request
  (seeded or admitted mid-solve), exactly the result of that request's
  closed-batch solve at the same padding shape: values AND iteration
  counters, for all three registered kinds, regardless of the admission
  schedule.  Checked against the masked driver, the compacted driver, and
  a loop of single solves; on the host and on sharded lanes (2 devices and
  the full emulated count).
* ADMISSION — the ``admit`` hook is offered every freed slot (including
  slots vacated before the first cycle by born-dead instances), may
  decline and be re-offered later, must not over-return, and a payload
  that fails at admission fails ALONE (``on_error``) without aborting the
  session.
* SERVING — with ``AsyncSolverEngine(refill=True)`` queued requests are
  admitted into an in-flight session at cycle boundaries and every
  ticket's future resolves the moment ITS instance converges, not at
  batch drain; a poisoned request admitted mid-solve fails only its own
  future; a session that aborts outright falls back to solo solves so no
  future is lost; the deprecated ``submit_*`` / ``*_kw`` spellings
  warn-and-delegate through the refill path.
* PROPERTY — for random ragged request streams (sizes, kinds, arrival
  order), engine results equal per-request reference solves whatever the
  refill schedule turned out to be (hypothesis when installed, fixed
  seeds otherwise — tests/hypothesis_compat.py).

Timing discipline matches test_scheduler.py: threaded tests assert on
events with generous budgets, never on sleeps; determinism in the
admission tests comes from gating the session INSIDE its finalize hook,
not from racing wall clocks.  Multi-device is emulated exactly as in
test_shard.py: a slow subprocess test relaunches this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import os
import pathlib
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.kinds as kinds_mod
from hypothesis_compat import given, settings, st
from repro.core.assignment.ref import optimal_weight
from repro.core.batch import solve_batch
from repro.core.matching.ref import hopcroft_karp, random_bipartite
from repro.core.maxflow.grid import GridProblem
from repro.core.maxflow.ref import maxflow_grid_ref, random_grid_problem
from repro.core.refill import RefillSolver, refill_runtime
from repro.launch.mesh import make_solver_mesh
from repro.serve.engine import SolverEngine
from repro.serve.metrics import SchedulerMetrics
from repro.serve.scheduler import AsyncSolverEngine

pytestmark = pytest.mark.refill

N_DEV = len(jax.devices())
FORCE_FLAG = "--xla_force_host_platform_device_count=8"
multi = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices; covered via the subprocess test")
SHARD_COUNTS = sorted({2, N_DEV}) if N_DEV >= 2 else []

WAIT_S = 120.0
LONG_DEADLINE_MS = 600_000.0


def _grid(rng, h, w, easy=False):
    cap, cs, ct = random_grid_problem(rng, h, w)
    if easy:
        cs = np.minimum(cs, 1.0)
    return GridProblem(*map(jnp.asarray, (cap, cs, ct)))


def _assert_trees_equal(a, b):
    for name, la, lb in zip(a._fields, a, b):
        if isinstance(la, tuple):  # nested NamedTuple (GridFlowState)
            _assert_trees_equal(la, lb)
        else:
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb), err_msg=name)


def _queue_admit(queue, chunk=None):
    """An ``admit`` callback popping up to ``chunk`` payloads per offer."""
    def admit(n_free):
        take = n_free if chunk is None else min(chunk, n_free)
        out, queue[:take] = list(queue[:take]), []
        return out
    return admit


@pytest.mark.slow  # full refill suite in a fresh 8-dev process
@pytest.mark.skipif(N_DEV >= 2, reason="already multi-device")
def test_forced_multi_device_subprocess():
    """Relaunch this file under 8 emulated host devices and require green."""
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", str(__file__)],
        cwd=root, env=env, capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, f"\n--- stdout ---\n{r.stdout}\n{r.stderr}"
    assert "passed" in r.stdout


# ------------------------------------------------------------- bit-match

def _kind_cases(seed):
    """(kind, shape, payloads) per kind: ragged sizes, ragged difficulty,
    and a born-dead instance where the kind can express one."""
    rng = np.random.default_rng(seed)
    probs = [_grid(rng, 8, 8), _grid(rng, 5, 7, easy=True), _grid(rng, 8, 8),
             _grid(rng, 6, 6, easy=True), _grid(rng, 8, 8, easy=True),
             _grid(rng, 7, 5)]
    ws = [rng.integers(0, 50, (n, n)) for n in (6, 4, 6, 5, 3, 6)]
    adjs = [random_bipartite(rng, 7, 9, 0.25) for _ in range(5)]
    adjs.append(np.zeros((3, 4), bool))          # born-dead: no edges
    return [("maxflow", (8, 8), probs), ("assignment", (6,), ws),
            ("matching", (7, 9), adjs)]


@pytest.mark.parametrize("chunk", [None, 1])
def test_refill_bitmatches_closed_batch_all_kinds(chunk):
    """Seed half, admit the rest mid-solve (all at once or one per offer):
    every result — values and counters — equals the closed-batch solve of
    the same requests at the same padding shape, masked and compacted."""
    for kind, shape, payloads in _kind_cases(0):
        queue = list(payloads[3:])
        got = RefillSolver(kind, shape=shape, capacity=3).run(
            payloads[:3], admit=_queue_admit(queue, chunk))
        assert not queue and sorted(got) == list(range(len(payloads)))
        masked = solve_batch(kind, payloads, bucket="max")
        compacted = solve_batch(kind, payloads, bucket="max", compact=True)
        for i in range(len(payloads)):
            _assert_trees_equal(got[i], masked[i])
            _assert_trees_equal(got[i], compacted[i])


def test_refill_capacity_one_is_a_loop_of_singles():
    """A 1-slot session IS sequential solving: bit-match the loop of
    single solves (same padding shape — all payloads at the bucket max)."""
    rng = np.random.default_rng(1)
    probs = [_grid(rng, 8, 8, easy=bool(i % 2)) for i in range(4)]
    queue = list(probs[1:])
    got = RefillSolver("maxflow", shape=(8, 8), capacity=1).run(
        probs[:1], admit=_queue_admit(queue))
    singles = [solve_batch("maxflow", [p], bucket="max")[0] for p in probs]
    for i, want in enumerate(singles):
        _assert_trees_equal(got[i], want)


def test_refill_underseeded_session_offers_free_slots_before_cycle_zero():
    """Seeding fewer payloads than capacity must offer the empty slots to
    ``admit`` before the first cycle — not leave them inert forever."""
    rng = np.random.default_rng(2)
    ws = [rng.integers(0, 50, (5, 5)) for _ in range(4)]
    offers = []

    def admit(n_free):
        offers.append(n_free)
        out, ws_left[:] = list(ws_left), []
        return out[:n_free]

    ws_left = list(ws[1:])
    got = RefillSolver("assignment", shape=(5,), capacity=4).run(
        ws[:1], admit=admit)
    assert offers[0] == 3, "empty seed slots not offered before cycle 0"
    for i, w in enumerate(ws):
        assert int(got[i].weight) == optimal_weight(w)


def test_refill_decline_then_admit_is_reoffered():
    """Declining an offer must not retire the slot: while anything is
    still live, the hook is offered the freed capacity again at every
    later cycle boundary.  (A decline with NOTHING live ends the session —
    that is the documented quiescence rule, not a retired slot.)"""
    rng = np.random.default_rng(3)
    hard = _grid(rng, 12, 12)
    easies = [_grid(rng, 8, 8, easy=True) for _ in range(2)]
    probs = [hard, easies[0], easies[1]]
    # fine-grained cycles so the easy seed frees its slot several
    # boundaries before the hard one converges
    kw = {"rounds_per_heuristic": 8}
    want = solve_batch("maxflow", probs, bucket="max", **kw)
    assert int(want[0].rounds) >= int(want[1].rounds) + 3 * 8, \
        "hard seed not hard enough — re-offer path untested"
    calls = {"n": 0}

    def admit(n_free):
        calls["n"] += 1
        if calls["n"] < 3:                       # decline twice
            return []
        out, queue[:] = list(queue), []
        return out[:n_free]

    queue = [easies[1]]
    got = RefillSolver("maxflow", shape=(12, 12), capacity=2, **kw).run(
        [hard, easies[0]], admit=admit)
    assert calls["n"] >= 3 and not queue
    for i in range(3):
        _assert_trees_equal(got[i], want[i])


def test_refill_delivers_in_convergence_order():
    """``on_result`` fires the moment an instance converges: an easy
    batch-mate is delivered while the hard seed is still solving."""
    rng = np.random.default_rng(4)
    hard, easy = _grid(rng, 8, 8), _grid(rng, 8, 8, easy=True)
    r_hard, r_easy = solve_batch("maxflow", [hard, easy], bucket="max")
    assert int(r_hard.rounds) > int(r_easy.rounds), \
        "stream not ragged — delivery-order path untested"
    order = []
    RefillSolver("maxflow", shape=(8, 8), capacity=2).run(
        [hard, easy], on_result=lambda i, r: order.append(i))
    assert order == [1, 0], f"delivery order {order} is not convergence order"


@multi
def test_refill_bitmatches_on_sharded_lanes():
    """Refill into per-device compaction lanes (2-way and the full mesh):
    admissions stay within lanes, results still bit-match closed batches."""
    for s in SHARD_COUNTS:
        mesh = make_solver_mesh(s)
        for kind, shape, payloads in _kind_cases(5):
            cap = -(-4 // s) * s                 # divisible across shards
            queue = list(payloads[2:])
            got = RefillSolver(kind, shape=shape, capacity=cap,
                               mesh=mesh).run(payloads[:2],
                                              admit=_queue_admit(queue))
            assert not queue
            want = solve_batch(kind, payloads, bucket="max")
            for i in range(len(payloads)):
                _assert_trees_equal(got[i], want[i])


# ----------------------------------------------------- admission contract

def test_refill_admit_contract():
    rng = np.random.default_rng(6)
    ws = [rng.integers(0, 50, (4, 4)) for _ in range(3)]
    with pytest.raises(ValueError, match="capacity"):
        RefillSolver("assignment", shape=(4,), capacity=0)
    with pytest.raises(ValueError, match="initial payloads"):
        RefillSolver("assignment", shape=(4,), capacity=2).run(ws)
    with pytest.raises(ValueError, match="at most n_free"):
        RefillSolver("assignment", shape=(4,), capacity=1).run(
            ws[:1], admit=lambda n: ws)          # over-returns
    s = RefillSolver("assignment", shape=(4,), capacity=1)
    assert s.fits(ws[0]) and not s.fits(rng.integers(0, 5, (6, 6)))
    # a kind without a registered runtime is a ValueError naming the gap
    real = kinds_mod.get_kind("maxflow")
    kinds_mod._REGISTRY["maxflow"] = real._replace(refill=None)
    try:
        with pytest.raises(ValueError, match="no refill runtime"):
            refill_runtime("maxflow")
    finally:
        kinds_mod._REGISTRY["maxflow"] = real


def test_refill_bad_admission_fails_alone():
    """A payload that fails validation at admission reports through
    ``on_error`` with its own request index; the session continues and
    every other request still bit-matches."""
    rng = np.random.default_rng(7)
    ws = [rng.integers(0, 50, (5, 5)) for _ in range(3)]
    bad = np.ones((5, 5))                        # float: validator rejects
    queue = [ws[1], bad, ws[2]]
    errors = []
    got = RefillSolver("assignment", shape=(5,), capacity=1).run(
        ws[:1], admit=_queue_admit(queue, 1),
        on_error=lambda i, e: errors.append((i, e)))
    assert [i for i, _ in errors] == [2]         # arrival index of ``bad``
    assert isinstance(errors[0][1], ValueError)
    want = solve_batch("assignment", ws, bucket="max")
    for got_i, want_i in zip((got[0], got[1], got[3]), want):
        _assert_trees_equal(got_i, want_i)
    # without on_error the same failure aborts the session
    with pytest.raises(ValueError, match="malformed assignment"):
        RefillSolver("assignment", shape=(5,), capacity=1).run(
            ws[:1], admit=_queue_admit([bad], 1))


# ------------------------------------------------- serving: mid-solve admission

def _gated_refill_factory(real_kind, started, gate, poison=None):
    """Wrap a kind's refill runtime so the FIRST finalize blocks on
    ``gate`` (signalling ``started``) — pinning the session mid-solve so a
    test can submit requests that can only complete via admission — and,
    optionally, so cropping a ``poison``-marked payload raises."""
    def factory(**kw):
        rt = real_kind.refill(**kw)

        def finalize(problems, st1, r):
            if not started.is_set():
                started.set()
                assert gate.wait(timeout=WAIT_S), "test gate never opened"
            return rt.finalize(problems, st1, r)

        def crop(res1, shape, payload):
            if poison is not None \
                    and int(np.asarray(payload).ravel()[0]) == poison:
                raise RuntimeError("poisoned crop")
            return rt.crop(res1, shape, payload)

        return rt._replace(finalize=finalize, crop=crop)
    return factory


@pytest.mark.serve
def test_async_refill_admits_mid_solve_and_resolves_per_instance(monkeypatch):
    """Deterministic mid-solve admission: the session is pinned inside the
    seed's finalize; requests submitted while it is pinned can ONLY
    complete through cycle-boundary admission (deadline is far away, size
    trigger unreachable), and the seed's future resolves FIRST — per
    instance, not at session drain."""
    started, gate = threading.Event(), threading.Event()
    real = kinds_mod.get_kind("assignment")
    monkeypatch.setitem(
        kinds_mod._REGISTRY, "assignment",
        real._replace(refill=_gated_refill_factory(real, started, gate)))

    rng = np.random.default_rng(8)
    ws = [rng.integers(0, 50, (5, 5)) for _ in range(4)]
    order = []
    with AsyncSolverEngine(max_batch=4, max_delay_ms=LONG_DEADLINE_MS,
                           refill=True) as eng:
        seed_fut = eng.submit("assignment", ws[0])
        seed_fut.add_done_callback(lambda f: order.append("seed"))
        eng.flush_now()                          # open the session
        assert started.wait(timeout=WAIT_S), "session never reached finalize"
        # the session is pinned: these can only resolve via admission
        futs = [eng.submit("assignment", w) for w in ws[1:]]
        for i, f in enumerate(futs):
            f.add_done_callback(lambda _f, i=i: order.append(i))
        gate.set()
        res = [f.result(timeout=WAIT_S) for f in futs]
        assert int(seed_fut.result(timeout=WAIT_S).weight) == \
            optimal_weight(ws[0])
        snap = eng.metrics.snapshot()
    for w, r in zip(ws[1:], res):
        assert int(r.weight) == optimal_weight(w)
    assert order[0] == "seed", \
        f"seed future resolved at {order.index('seed')}, not first: {order}"
    assert snap["refill"]["sessions"].get("assignment", 0) >= 1
    assert snap["refill"]["admitted"].get("assignment", 0) >= 3
    assert snap["refill"]["utilization"] is not None
    assert snap["tickets"]["completed"] == 4


@pytest.mark.serve
def test_async_refill_poison_admitted_mid_solve_fails_alone(monkeypatch):
    """A poisoned request ADMITTED into an in-flight session fails only
    its own future; the seed and the other admissions still resolve."""
    POISON = 777
    started, gate = threading.Event(), threading.Event()
    real = kinds_mod.get_kind("assignment")
    monkeypatch.setitem(
        kinds_mod._REGISTRY, "assignment",
        real._replace(refill=_gated_refill_factory(
            real, started, gate, poison=POISON)))

    rng = np.random.default_rng(9)
    ws = [rng.integers(0, 50, (5, 5)) for _ in range(3)]
    poisoned = ws[1].copy()
    poisoned.flat[0] = POISON
    with AsyncSolverEngine(max_batch=8, max_delay_ms=LONG_DEADLINE_MS,
                           refill=True) as eng:
        seed_fut = eng.submit("assignment", ws[0])
        eng.flush_now()
        assert started.wait(timeout=WAIT_S)
        futs = [eng.submit("assignment", w) for w in (poisoned, ws[2])]
        gate.set()
        with pytest.raises(RuntimeError, match="poisoned"):
            futs[0].result(timeout=WAIT_S)
        assert int(futs[1].result(timeout=WAIT_S).weight) == \
            optimal_weight(ws[2])
        assert int(seed_fut.result(timeout=WAIT_S).weight) == \
            optimal_weight(ws[0])
        snap = eng.metrics.snapshot()
    assert snap["tickets"]["failed"] == 1
    assert snap["tickets"]["completed"] == 2


@pytest.mark.serve
def test_async_refill_session_abort_falls_back_to_solo(monkeypatch):
    """If the session itself detonates (init raises), the lane's
    poison-isolation fallback re-solves every request solo through the
    closed-batch path — no future is ever lost."""
    real = kinds_mod.get_kind("assignment")

    def broken_factory(**kw):
        rt = real.refill(**kw)
        def boom(stacked):
            raise RuntimeError("session init detonated")
        return rt._replace(init=boom)

    monkeypatch.setitem(kinds_mod._REGISTRY, "assignment",
                        real._replace(refill=broken_factory))
    rng = np.random.default_rng(10)
    ws = [rng.integers(0, 50, (5, 5)) for _ in range(3)]
    with AsyncSolverEngine(max_batch=3, max_delay_ms=LONG_DEADLINE_MS,
                           refill=True) as eng:
        futs = [eng.submit("assignment", w) for w in ws]
        for w, f in zip(ws, futs):
            assert int(f.result(timeout=WAIT_S).weight) == optimal_weight(w)


@pytest.mark.serve
def test_async_refill_bitmatches_stream():
    """refill=True serving == closed-batch serving == single solves for a
    recorded mixed-kind stream (the scheduler-level bit-match layer)."""
    rng = np.random.default_rng(11)
    probs = [_grid(rng, 8, 8, easy=bool(i % 2)) for i in range(8)]
    adjs = [random_bipartite(rng, 6, 7, 0.3) for _ in range(4)]
    with AsyncSolverEngine(max_batch=4, max_delay_ms=LONG_DEADLINE_MS,
                           refill=True) as eng:
        f_futs = [eng.submit("maxflow", p) for p in probs]
        m_futs = [eng.submit("matching", a) for a in adjs]
        eng.flush_now()
        f_res = [f.result(timeout=WAIT_S) for f in f_futs]
        m_res = [f.result(timeout=WAIT_S) for f in m_futs]
        snap = eng.metrics.snapshot()
    assert sum(snap["refill"]["sessions"].values()) >= 2
    for lo in range(0, len(probs), 4):           # same 4-chunks as the popper
        want = solve_batch("maxflow", probs[lo:lo + 4], bucket="max")
        for got_i, want_i in zip(f_res[lo:lo + 4], want):
            _assert_trees_equal(got_i, want_i)
    for got_i, want_i in zip(m_res, solve_batch("matching", adjs,
                                                bucket="max")):
        _assert_trees_equal(got_i, want_i)


@pytest.mark.serve
@multi
def test_async_refill_sharded():
    """Continuous batching on a device mesh: sessions run on each lane's
    sub-mesh with capacity rounded to its shard count; results still
    match single solves."""
    for s in SHARD_COUNTS:
        rng = np.random.default_rng(12 + s)
        probs = [_grid(rng, 8, 8, easy=bool(i % 2)) for i in range(10)]
        with AsyncSolverEngine(max_batch=4, max_delay_ms=LONG_DEADLINE_MS,
                               refill=True, mesh=make_solver_mesh(s),
                               n_lanes=2) as eng:
            futs = [eng.submit("maxflow", p) for p in probs]
            eng.flush_now()
            res = [f.result(timeout=WAIT_S) for f in futs]
            snap = eng.metrics.snapshot()
        assert snap["refill"]["sessions"].get("maxflow", 0) >= 1
        for p, r in zip(probs, res):
            assert float(r.flow) == maxflow_grid_ref(
                np.asarray(p.cap_nbr), np.asarray(p.cap_src),
                np.asarray(p.cap_sink))


# --------------------------------------------- deprecated-shim coverage

@pytest.mark.serve
def test_deprecated_spellings_flow_through_refill_path():
    """``submit_maxflow`` / ``submit_assignment`` and the ``*_kw`` ctor
    spellings warn-and-delegate INTO the refill path: the session uses the
    deprecated kwargs and the refill counters prove the route taken."""
    rng = np.random.default_rng(13)
    probs = [_grid(rng, 12, 12) for _ in range(2)]
    ws = [rng.integers(0, 50, (5, 5)) for _ in range(2)]
    # max_rounds far below what these instances need: if the deprecated
    # kwargs were dropped on the refill path, the solves would CONVERGE —
    # the unconverged results below are proof the knob flowed through
    assert all(int(r.rounds) > 32
               for r in solve_batch("maxflow", probs, bucket="max"))
    with pytest.warns(DeprecationWarning, match="maxflow_kw"):
        eng = AsyncSolverEngine(max_batch=2, max_delay_ms=LONG_DEADLINE_MS,
                                refill=True, maxflow_kw={"max_rounds": 32})
    with eng:
        with pytest.warns(DeprecationWarning, match="submit_maxflow"):
            f_futs = [eng.submit_maxflow(p) for p in probs]
        with pytest.warns(DeprecationWarning, match="submit_assignment"):
            a_futs = [eng.submit_assignment(w) for w in ws]
        f_res = [f.result(timeout=WAIT_S) for f in f_futs]
        a_res = [f.result(timeout=WAIT_S) for f in a_futs]
        snap = eng.metrics.snapshot()
    assert snap["refill"]["sessions"].get("maxflow", 0) >= 1
    assert snap["refill"]["sessions"].get("assignment", 0) >= 1
    assert all(not bool(r.converged) and int(r.rounds) == 32 for r in f_res)
    want = solve_batch("maxflow", probs, bucket="max", max_rounds=32)
    for got_i, want_i in zip(f_res, want):
        _assert_trees_equal(got_i, want_i)
    for w, r in zip(ws, a_res):
        assert int(r.weight) == optimal_weight(w)


def test_sync_engine_refill_session_inherits_solver_kw():
    """``SolverEngine.refill_session`` folds the engine's per-kind solver
    kwargs (deprecated spellings included) into the session."""
    with pytest.warns(DeprecationWarning, match="maxflow_kw"):
        eng = SolverEngine(maxflow_kw={"max_rounds": 32})
    rng = np.random.default_rng(14)
    probs = [_grid(rng, 12, 12) for _ in range(2)]
    got = eng.refill_session("maxflow", shape=(12, 12), capacity=2).run(probs)
    assert all(not bool(got[i].converged) for i in range(2))
    want = solve_batch("maxflow", probs, bucket="max", max_rounds=32)
    for i in range(2):
        _assert_trees_equal(got[i], want[i])


# ----------------------------------------------------------- metrics unit

def test_refill_metrics_snapshot():
    m = SchedulerMetrics(ewma_alpha=1.0)
    snap = m.snapshot()["refill"]
    assert snap == {"sessions": {}, "admitted": {},
                    "slot_occupancy_ewma": {}, "utilization": None}
    m.record_refill_session("maxflow")
    m.record_refill_admit("maxflow", 3)
    m.record_refill_cycle("maxflow", 1.0)
    m.record_refill_cycle("maxflow", 0.5)
    snap = m.snapshot()["refill"]
    assert snap["sessions"] == {"maxflow": 1}
    assert snap["admitted"] == {"maxflow": 3}
    assert snap["slot_occupancy_ewma"]["maxflow"] == 0.5   # alpha=1: last
    assert snap["utilization"] == 0.75                     # mean of cycles


# ------------------------------------------------- property: ragged streams

def _check_stream(seed):
    """One random ragged stream through ``AsyncSolverEngine(refill=True)``:
    random sizes, kinds, and arrival order; every future must equal its
    per-request REFERENCE solve no matter how the refill schedule fell."""
    rng = np.random.default_rng(seed)
    reqs = []                                    # (kind, payload, checker)
    for _ in range(int(rng.integers(6, 13))):
        k = int(rng.integers(3))
        if k == 0:
            h, w = int(rng.integers(4, 9)), int(rng.integers(4, 9))
            p = _grid(rng, h, w, easy=bool(rng.integers(2)))
            ref = maxflow_grid_ref(np.asarray(p.cap_nbr),
                                   np.asarray(p.cap_src),
                                   np.asarray(p.cap_sink))
            reqs.append(("maxflow", p,
                         lambda r, ref=ref: float(r.flow) == ref))
        elif k == 1:
            n = int(rng.integers(3, 7))
            w = rng.integers(0, 50, (n, n))
            ref = optimal_weight(w)
            reqs.append(("assignment", w,
                         lambda r, ref=ref: int(r.weight) == ref))
        else:
            nl, nr = int(rng.integers(3, 8)), int(rng.integers(3, 8))
            a = random_bipartite(rng, nl, nr, float(rng.uniform(0.1, 0.5)))
            ref = hopcroft_karp(a)[2]
            reqs.append(("matching", a,
                         lambda r, ref=ref: int(r.cardinality) == ref))
    # pow2 bucketing keeps the compile-shape set small across examples
    with AsyncSolverEngine(max_batch=int(rng.integers(2, 5)),
                           max_delay_ms=float(rng.uniform(1.0, 20.0)),
                           refill=True, bucket="pow2",
                           n_lanes=int(rng.integers(1, 3))) as eng:
        futs = [eng.submit(kind, payload) for kind, payload, _ in reqs]
        if rng.integers(2):
            eng.flush_now()
        results = [f.result(timeout=WAIT_S) for f in futs]
    for (kind, _, check), r in zip(reqs, results):
        assert check(r), f"{kind} result diverged from reference (seed " \
                         f"{seed})"


@pytest.mark.serve
@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=2**32 - 1))
def test_property_ragged_streams_match_references(seed):
    _check_stream(seed)


@pytest.mark.serve
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fixed_seed_ragged_streams_match_references(seed):
    """The hypothesis property above pinned to fixed seeds, so the stream
    invariant is exercised even where hypothesis is not installed."""
    _check_stream(seed)
