"""bfs_relabel pallas kernel vs pure-jnp oracle (balanced backend relabel).

The contract: one ``bfs_relabel_sweeps`` launch == ``SWEEPS`` joint
min-plus relaxation sweeps of both wavefront planes (``ref.
bfs_relabel_sweeps_ref``), and the ops-level fixpoint driver reproduces
the eager bidirectional fixpoint + combine (``ref.
bfs_relabel_heights_ref``) bit-exactly — single and batched.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.maxflow.ref import (checkerboard_problem, long_path_problem,
                                    random_grid_problem)
from repro.kernels.bfs_relabel.kernel import INF_H, SWEEPS, bfs_relabel_sweeps
from repro.kernels.bfs_relabel.ops import bfs_relabel_heights
from repro.kernels.bfs_relabel.ref import (bfs_relabel_heights_ref,
                                           bfs_relabel_sweeps_ref)

pytestmark = pytest.mark.kernels


def _seeds(cap_src, cap_sink, n_nodes):
    seed_t = jnp.where(jnp.asarray(cap_sink) > 0, jnp.int32(1), INF_H)
    seed_s = jnp.where(jnp.asarray(cap_src) > 0, jnp.int32(n_nodes) + 1,
                       INF_H)
    return seed_t, seed_s


@pytest.mark.parametrize("H,W,seed", [(8, 8, 0), (16, 32, 1), (32, 32, 2)])
def test_sweeps_kernel_vs_ref(H, W, seed):
    rng = np.random.default_rng(seed)
    cap, cs, ct = random_grid_problem(rng, H, W)
    n = H * W + 2
    seed_t, seed_s = _seeds(cs, ct, n)
    cap = jnp.asarray(cap)
    k_dt, k_ds = bfs_relabel_sweeps(
        cap[:, None], seed_t[None], seed_s[None], seed_t[None], seed_s[None],
        interpret=True)
    r_dt, r_ds = bfs_relabel_sweeps_ref(cap, seed_t, seed_s, seed_t, seed_s,
                                        sweeps=SWEEPS)
    np.testing.assert_array_equal(np.asarray(k_dt[0]), np.asarray(r_dt))
    np.testing.assert_array_equal(np.asarray(k_ds[0]), np.asarray(r_ds))


def test_sweeps_batched_grid_matches_singles():
    """The (B,) pallas grid dim == per-instance launches, bit-exact."""
    rng = np.random.default_rng(3)
    B, H, W = 4, 12, 12
    probs = [random_grid_problem(rng, H, W) for _ in range(B)]
    n = H * W + 2
    cap = jnp.asarray(np.stack([p[0] for p in probs], axis=1))  # (4,B,H,W)
    seeds = [_seeds(p[1], p[2], n) for p in probs]
    seed_t = jnp.stack([s[0] for s in seeds])
    seed_s = jnp.stack([s[1] for s in seeds])
    b_dt, b_ds = bfs_relabel_sweeps(cap, seed_t, seed_s, seed_t, seed_s,
                                    interpret=True)
    for b in range(B):
        s_dt, s_ds = bfs_relabel_sweeps(
            cap[:, b:b + 1], seed_t[b:b + 1], seed_s[b:b + 1],
            seed_t[b:b + 1], seed_s[b:b + 1], interpret=True)
        np.testing.assert_array_equal(np.asarray(b_dt[b]), np.asarray(s_dt[0]))
        np.testing.assert_array_equal(np.asarray(b_ds[b]), np.asarray(s_ds[0]))


@pytest.mark.parametrize("maker,seed", [
    (lambda rng: random_grid_problem(rng, 16, 16), 0),
    (lambda rng: random_grid_problem(rng, 8, 24), 5),
    (lambda rng: long_path_problem(8, 8), 0),
    (lambda rng: checkerboard_problem(8, 8), 0),
])
def test_heights_driver_vs_fixpoint_ref(maker, seed):
    """ops.bfs_relabel_heights == eager fixpoint+combine oracle, with a
    non-trivial h_prev (the combine must never lower existing heights)."""
    rng = np.random.default_rng(seed)
    cap, cs, ct = maker(rng)
    H, W = cs.shape
    n = H * W + 2
    h_prev = jnp.asarray(rng.integers(0, n, (H, W)), jnp.int32)
    got = bfs_relabel_heights(jnp.asarray(cap), jnp.asarray(cs),
                              jnp.asarray(ct), h_prev, n, n, interpret=True)
    want = bfs_relabel_heights_ref(jnp.asarray(cap), jnp.asarray(cs),
                                   jnp.asarray(ct), h_prev, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_heights_batched_matches_singles():
    rng = np.random.default_rng(9)
    B, H, W = 3, 10, 10
    probs = [random_grid_problem(rng, H, W) for _ in range(B)]
    n = H * W + 2
    cap = jnp.asarray(np.stack([p[0] for p in probs], axis=1))
    cs = jnp.asarray(np.stack([p[1] for p in probs]))
    ct = jnp.asarray(np.stack([p[2] for p in probs]))
    h_prev = jnp.zeros((B, H, W), jnp.int32)
    batched = bfs_relabel_heights(cap, cs, ct, h_prev, n, n, interpret=True)
    for b in range(B):
        single = bfs_relabel_heights(cap[:, b], cs[b], ct[b], h_prev[b], n, n,
                                     interpret=True)
        np.testing.assert_array_equal(np.asarray(batched[b]),
                                      np.asarray(single))


def test_bidirectional_labels_disconnected_pocket():
    """A cell cut off from the sink but residually connected to the source
    gets the exact return gradient N + dist, not the flat gap value N."""
    H, W = 4, 4
    cap = np.zeros((4, H, W), np.float32)
    cs = np.zeros((H, W), np.float32)
    ct = np.zeros((H, W), np.float32)
    cs[0, 0] = 5.0        # source feeds the top-left pocket
    ct[3, 3] = 5.0        # sink sits in the far corner, unreachable
    cap[3, 0, 0] = 1.0    # (0,0) -> (0,1): RIGHT edge only, dead ends there
    n = H * W + 2
    h = bfs_relabel_heights(jnp.asarray(cap), jnp.asarray(cs),
                            jnp.asarray(ct), jnp.zeros((H, W), jnp.int32),
                            n, n, interpret=True)
    h = np.asarray(h)
    assert h[0, 0] == n + 1                  # adjacent to the source
    assert h[3, 3] == 1                      # adjacent to the sink
    assert h[1, 1] == n                      # doubly unreached -> gap value
    # (0,1) has no residual out-edges at all (cap stores OUT capacities),
    # so neither wavefront reaches it either:
    assert h[0, 1] == n
