"""End-to-end system tests: train loop behaviour, resume-exactness,
generation, and the dry-run machinery on a tiny in-process mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, smoke_variant
from repro.data.pipeline import DataConfig, host_batch
from repro.models.layers import Sharder
from repro.models.model import init_model
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import greedy_generate
from repro.train.step import TrainConfig, init_train_state, make_train_step
from repro.checkpoint import store
from repro.roofline import cost_analysis_dict

SHD = Sharder()


def _train(cfg, tcfg, steps, state=None, start=0, seed=0):
    params, axes = init_model(cfg, jax.random.PRNGKey(seed))
    if state is None:
        state = init_train_state(cfg, tcfg, params)
    step_fn = jax.jit(make_train_step(cfg, axes, tcfg, SHD))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4,
                      seed=seed, copy_prob=0.8)
    losses = []
    for s in range(start, steps):
        b = host_batch(dcfg, s, 0, 1)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    return state, losses


def test_loss_decreases_smollm():
    cfg = smoke_variant(get_config("smollm-135m"))
    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr_peak=3e-3, warmup_steps=5, decay_steps=40))
    _, losses = _train(cfg, tcfg, 30)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_resume_is_exact(tmp_path):
    """Checkpoint at step 3, resume, and land bit-identically at step 6."""
    cfg = smoke_variant(get_config("smollm-135m"))
    tcfg = TrainConfig(optimizer=AdamWConfig(warmup_steps=2, decay_steps=10))
    state_a, _ = _train(cfg, tcfg, 6)

    state_b, _ = _train(cfg, tcfg, 3)
    store.save(str(tmp_path), 3, state_b)
    restored = store.restore(str(tmp_path), 3, state_b)
    state_c, _ = _train(cfg, tcfg, 6, state=restored, start=3)

    for a, c in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_c.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_microbatching_matches_full_batch():
    """grad accumulation over 2 microbatches ~= single big batch step."""
    cfg = smoke_variant(get_config("smollm-135m"))
    t1 = TrainConfig(optimizer=AdamWConfig(warmup_steps=1, decay_steps=10),
                     num_microbatches=1)
    t2 = TrainConfig(optimizer=AdamWConfig(warmup_steps=1, decay_steps=10),
                     num_microbatches=2)
    s1, l1 = _train(cfg, t1, 2)
    s2, l2 = _train(cfg, t2, 2)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_greedy_generate_runs():
    cfg = smoke_variant(get_config("smollm-135m"))
    params, axes = init_model(cfg, jax.random.PRNGKey(0))
    prompts = jnp.ones((2, 8), jnp.int32)
    out = greedy_generate(cfg, params, axes, SHD, prompts, max_new=6)
    assert out.shape == (2, 6)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))


def test_flow_router_trains():
    """MoE with the paper's flow router: losses stay finite and decrease."""
    cfg = smoke_variant(get_config("phi3.5-moe-42b-a6.6b"))
    assert cfg.moe.router == "flow"
    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr_peak=2e-3, warmup_steps=3, decay_steps=25))
    _, losses = _train(cfg, tcfg, 15)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_dryrun_cell_on_host_mesh():
    """The cell-builder machinery lowers on an in-process 1-device mesh."""
    import dataclasses
    from repro.configs import base as cb
    from repro.launch.specs import build_cell
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("smollm-135m")
    tiny = dataclasses.replace(smoke_variant(cfg), name=cfg.name + "-tiny")
    cb._REGISTRY[tiny.name] = tiny
    try:
        cell = build_cell(tiny.name, "train_4k", mesh)
        with mesh:
            lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                              donate_argnums=cell.donate_argnums
                              ).lower(*cell.args)
            compiled = lowered.compile()
        # normalized across jax versions (list-of-dicts vs dict); a train
        # step must report real FLOPs or the roofline numbers are garbage
        cost = cost_analysis_dict(compiled)
        assert cost.get("flops", 0.0) > 0.0, cost
    finally:
        cb._REGISTRY.pop(tiny.name, None)
