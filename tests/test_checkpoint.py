"""Checkpoint store contracts: atomicity, GC namespacing, validated restore.

The store (``repro.checkpoint.store``) promises:

* ATOMIC COMMIT — a checkpoint becomes visible all-at-once (tempdir +
  ``os.replace``, manifest written last); readers never observe a torn
  write, and a crashed writer leaves only an invisible ``.tmp_*`` dir.
* NAMESPACED GC — ``save(keep=)`` rotation touches ONLY committed
  ``step_<digits>`` directories: ``kv_*`` blob entries (the warm-start
  cache's spill target, docs/warmstart.md) and foreign directories a user
  drops into the checkpoint root survive every rotation.
* VALIDATED RESTORE — a leaf whose saved dtype/shape disagrees with
  ``like_tree`` (or with the checkpoint's own manifest) raises
  ``ValueError`` naming the leaf instead of silently casting.
* ELASTIC RESHARD — ``restore(shardings=)`` may target a different mesh
  than the save ran on; values are unchanged (exercised at 2 emulated
  devices here, at 8 via the subprocess relaunch / the CI flag).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store

N_DEV = len(jax.devices())
FORCE_FLAG = "--xla_force_host_platform_device_count=8"
multi = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices; covered via the subprocess test")


def _tree():
    return {"w": jnp.arange(24.0, dtype=jnp.float32).reshape(4, 6),
            "opt": {"mu": jnp.ones((4, 6), jnp.float32),
                    "count": jnp.int32(3)}}


# ------------------------------------------------------- atomic commit


def test_commit_is_atomic_and_manifest_marks_completion(tmp_path):
    store.save(str(tmp_path), 1, _tree())
    path = tmp_path / "step_00000001"
    assert (path / "manifest.json").exists()
    # no tempdir residue after a successful commit
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp_")]
    # a torn write (dir without manifest) is INVISIBLE to latest_step
    os.makedirs(tmp_path / "step_00000002")
    assert store.latest_step(str(tmp_path)) == 1
    # ... and an in-flight tempdir is too
    os.makedirs(tmp_path / ".tmp_ckpt_inflight")
    assert store.latest_step(str(tmp_path)) == 1


def test_failed_write_leaves_no_tempdir(tmp_path):
    class Boom:
        """A leaf whose materialization raises mid-write."""
        dtype = np.float32
        def __array__(self, *a, **k):
            raise RuntimeError("device fell over")

    with pytest.raises(RuntimeError, match="device fell over"):
        store.save(str(tmp_path), 5, {"x": Boom()})
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp_")]
    assert store.latest_step(str(tmp_path)) is None


# ------------------------------------------------------- GC namespacing


def test_gc_keeps_newest_in_step_order(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    # out-of-order saves: GC must order by STEP NUMBER, not mtime
    for s in (3, 1, 4, 0, 2):
        store.save(str(tmp_path), s, tree, keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_gc_skips_kv_and_foreign_dirs(tmp_path):
    store.put(str(tmp_path), "deadbeef", [np.arange(3)])
    os.makedirs(tmp_path / "users_notes")
    (tmp_path / "users_notes" / "todo.txt").write_text("keep me")
    (tmp_path / "loose_file").write_text("me too")
    tree = {"x": jnp.zeros((2,))}
    for s in range(4):
        store.save(str(tmp_path), s, tree, keep=1)
    names = set(os.listdir(tmp_path))
    assert "kv_deadbeef" in names
    assert "users_notes" in names and "loose_file" in names
    assert [d for d in names if d.startswith("step_")] == ["step_00000003"]
    got = store.get(str(tmp_path), "deadbeef")
    np.testing.assert_array_equal(got[0], np.arange(3))


def test_latest_step_ignores_foreign_dirs(tmp_path):
    store.save(str(tmp_path), 7, {"x": jnp.zeros((2,))})
    os.makedirs(tmp_path / "step_notanumber")
    os.makedirs(tmp_path / "stepping_stone")
    os.makedirs(tmp_path / "kv_abc123")
    assert store.latest_step(str(tmp_path)) == 7
    assert store.latest_step(str(tmp_path / "does_not_exist")) is None


# ------------------------------------------------------- validated restore


def test_restore_rejects_dtype_mismatch(tmp_path):
    store.save(str(tmp_path), 1, _tree())
    wrong = jax.tree.map(lambda a: jnp.asarray(a, jnp.int32), _tree())
    with pytest.raises(ValueError, match="refusing to cast"):
        store.restore(str(tmp_path), 1, wrong)


def test_restore_rejects_shape_mismatch(tmp_path):
    store.save(str(tmp_path), 1, _tree())
    wrong = _tree()
    wrong["w"] = jnp.zeros((6, 4), jnp.float32)
    with pytest.raises(ValueError, match="mismatch"):
        store.restore(str(tmp_path), 1, wrong)


def test_restore_rejects_leaf_count_mismatch(tmp_path):
    store.save(str(tmp_path), 1, _tree())
    with pytest.raises(ValueError, match="leaves"):
        store.restore(str(tmp_path), 1, {"only": jnp.zeros((2,))})


def test_restore_rejects_corrupt_shard(tmp_path):
    store.save(str(tmp_path), 1, _tree())
    path = tmp_path / "step_00000001"
    # tamper: manifest claims a different shape than the shard holds
    meta = json.loads((path / "manifest.json").read_text())
    meta["shapes"][0] = [9, 9]
    (path / "manifest.json").write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="corrupt checkpoint|mismatch"):
        store.restore(str(tmp_path), 1, _tree())


def test_kv_roundtrip_and_key_validation(tmp_path):
    tree = {"sol": jnp.arange(5.0), "meta": jnp.int32(2)}
    store.put(str(tmp_path), "cafe.01-x", tree)
    back = store.get(str(tmp_path), "cafe.01-x", like_tree=tree)
    np.testing.assert_array_equal(np.asarray(back["sol"]), np.arange(5.0))
    assert store.get(str(tmp_path), "absent") is None
    with pytest.raises(ValueError, match="invalid blob key"):
        store.put(str(tmp_path), "../escape", tree)
    # overwrite is atomic and last-write-wins
    store.put(str(tmp_path), "cafe.01-x",
              jax.tree.map(lambda a: a + 1, tree))
    back = store.get(str(tmp_path), "cafe.01-x", like_tree=tree)
    np.testing.assert_array_equal(np.asarray(back["sol"]),
                                  np.arange(5.0) + 1)


# ------------------------------------------------------- elastic reshard


@multi
def test_elastic_reshard_restore_two_devices(tmp_path):
    """Save unsharded, restore onto a 2-device mesh — values unchanged."""
    from jax.sharding import NamedSharding
    from repro.launch.mesh import batch_spec, make_solver_mesh

    tree = {"a": jnp.arange(32.0).reshape(8, 4),
            "b": jnp.arange(8, dtype=jnp.int32)}
    store.save(str(tmp_path), 3, tree)
    mesh = make_solver_mesh(2)
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, batch_spec(mesh)), tree)
    back = store.restore(str(tmp_path), 3, tree, shardings=shardings)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]), err_msg=k)
        assert len(back[k].sharding.device_set) == 2, k


@pytest.mark.slow  # fresh 8-device process re-runs this whole file
@pytest.mark.skipif(N_DEV >= 2, reason="already multi-device")
def test_forced_multi_device_subprocess():
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", str(__file__)],
        cwd=root, env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"\n--- stdout ---\n{r.stdout}\n{r.stderr}"
    assert "passed" in r.stdout
