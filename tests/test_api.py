"""Import-surface smoke test: repro.core exports the public solver API.

Guards the package façade (`src/repro/core/__init__.py`): every name in
``__all__`` resolves, the names are the SAME objects as their home modules'
(no shadow copies that could drift), and a tiny end-to-end solve works when
driven purely through ``repro.core``.
"""
import jax.numpy as jnp
import numpy as np

import repro.core as core


def test_all_names_resolve():
    missing = [n for n in core.__all__ if not hasattr(core, n)]
    assert not missing, f"__all__ names missing from repro.core: {missing}"
    assert sorted(core.__all__) == list(core.__all__), "__all__ not sorted"


def test_exports_are_home_module_objects():
    from repro.core.assignment import cost_scaling
    from repro.core import batch, kinds, masking, matching, solver_loop
    from repro.core.matching import bfs
    from repro.core.maxflow import grid
    assert core.maxflow_grid is grid.maxflow_grid
    assert core.maxflow_grid_batch is grid.maxflow_grid_batch
    assert core.GridProblem is grid.GridProblem
    assert core.solve_assignment is cost_scaling.solve_assignment
    assert core.solve_maxflow_batch is batch.solve_maxflow_batch
    assert core.solve_assignment_batch is batch.solve_assignment_batch
    assert core.solve_batch is batch.solve_batch
    assert core.prepare_buckets is batch.prepare_buckets
    assert core.solve_prepared is batch.solve_prepared
    assert core.PreparedBucket is batch.PreparedBucket
    assert core.SolverKind is kinds.SolverKind
    assert core.register_kind is kinds.register_kind
    assert core.get_kind is kinds.get_kind
    assert core.registered_kinds is kinds.registered_kinds
    assert core.match_bipartite is bfs.match_bipartite
    assert core.match_bipartite_batch is bfs.match_bipartite_batch
    assert core.MatchingResult is bfs.MatchingResult
    assert core.match_bipartite is matching.match_bipartite
    assert core.freeze is masking.freeze
    assert core.LoopSpec is solver_loop.LoopSpec
    assert core.run_masked is solver_loop.run_masked
    assert core.run_compacted is solver_loop.run_compacted


def test_registered_kinds_exported_and_complete():
    ks = core.registered_kinds()
    assert {"maxflow", "assignment", "matching"} <= set(ks)
    for k in ks:
        kind = core.get_kind(k)
        assert kind.name == k
        assert callable(kind.validate) and callable(kind.prepare_buckets)
        assert callable(kind.solve_prepared) and callable(kind.loop_spec)
        assert callable(kind.inert_problem)


def test_facade_end_to_end_smoke():
    w = np.asarray([[3, 1], [2, 4]])
    res = core.solve_assignment(jnp.asarray(w))
    assert bool(res.converged) and int(res.weight) == 7
    [r] = core.solve_assignment_batch([w], compact=True)
    assert int(r.weight) == 7
    adj = np.eye(3, dtype=bool)
    m = core.match_bipartite(adj)
    assert int(m.cardinality) == 3 and bool(m.converged)
    [mb] = core.solve_batch("matching", [adj])
    assert int(mb.cardinality) == 3
