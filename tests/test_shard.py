"""Sharded-vs-unsharded equivalence: shard_map over the batch axis is exact.

The contract under test (ROADMAP "device-mesh sharding", docs/batching.md):
partitioning the batch axis of ``maxflow_grid_batch`` / batched
``solve_assignment`` / the ``repro.core.batch`` ragged front ends across a
device mesh changes WHERE instances are solved, never WHAT is solved — every
result leaf bit-matches the unsharded batched solve. This holds because an
instance's trajectory never depends on its batch-mates (all reductions run
over the trailing data axes; liveness masks are per instance) and the
sharded body contains no collectives.

Multi-device is emulated on CPU: when this file runs in a single-device
process, ``test_forced_multi_device_subprocess`` relaunches it under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the flag must be set
before jax initializes, hence the subprocess). CI runs the file directly
with the flag exported — see .github/workflows/ci.yml.
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.assignment.cost_scaling import solve_assignment
from repro.core.batch import solve_assignment_batch, solve_maxflow_batch
from repro.core.maxflow.grid import GridProblem, maxflow_grid_batch
from repro.core.maxflow.ref import random_grid_problem
from repro.launch.mesh import (batch_spec, make_solver_mesh, shard_count,
                               solver_batch_axis)
from repro.serve.engine import SolverEngine

N_DEV = len(jax.devices())
FORCE_FLAG = "--xla_force_host_platform_device_count=8"
multi = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices; covered via the subprocess test")

# 2 and the full device count (8 under the forced flag) — the acceptance
# criterion asks for >=2 emulated devices; exercising two different shard
# counts also covers uneven real-work distribution.
SHARD_COUNTS = sorted({2, N_DEV}) if N_DEV >= 2 else []


def _grid_problems(seed, B, H, W):
    rng = np.random.default_rng(seed)
    return [GridProblem(*map(jnp.asarray, random_grid_problem(rng, H, W)))
            for _ in range(B)]


def _assert_trees_equal(a, b):
    for name, la, lb in zip(a._fields, a, b):
        if isinstance(la, tuple):  # nested NamedTuple (GridFlowState)
            _assert_trees_equal(la, lb)
        else:
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb), err_msg=name)


@pytest.mark.slow  # ~1 min: full shard suite in a fresh 8-device process
@pytest.mark.skipif(N_DEV >= 2, reason="already multi-device")
def test_forced_multi_device_subprocess():
    """Relaunch this file under 8 emulated host devices and require green."""
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", str(__file__)],
        cwd=root, env=env, capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, f"\n--- stdout ---\n{r.stdout}\n{r.stderr}"
    assert "passed" in r.stdout


def test_solver_mesh_shape():
    mesh = make_solver_mesh()
    assert mesh.axis_names == ("batch",)
    assert solver_batch_axis(mesh) == "batch"
    assert shard_count(mesh) == N_DEV
    assert batch_spec(mesh) == jax.sharding.PartitionSpec("batch")
    with pytest.raises(ValueError):
        make_solver_mesh(N_DEV + 1)
    with pytest.raises(ValueError):
        solver_batch_axis(mesh, "model")


@multi
@pytest.mark.parametrize("backend", ["xla", "multipush"])
def test_maxflow_sharded_bitmatch(backend):
    probs = _grid_problems(0, 8, 8, 8)
    from repro.core.batch import stack_grid_problems
    batch = stack_grid_problems(probs)
    base = maxflow_grid_batch(batch, backend=backend)
    for s in SHARD_COUNTS:
        res = maxflow_grid_batch(batch, backend=backend,
                                 mesh=make_solver_mesh(s))
        _assert_trees_equal(res, base)


@multi
@pytest.mark.parametrize("method", ["pushrelabel", "auction"])
def test_assignment_sharded_bitmatch(method):
    # heterogeneous difficulty: instance 0 has a shorter eps schedule, so
    # shards carry genuinely different amounts of work
    ws = np.stack([np.random.default_rng(i).integers(0, 101, (10, 10))
                   for i in range(8)])
    ws[0] //= 9
    base = solve_assignment(jnp.asarray(ws), method=method)
    for s in SHARD_COUNTS:
        res = solve_assignment(jnp.asarray(ws), method=method,
                               mesh=make_solver_mesh(s))
        _assert_trees_equal(res, base)


@multi
def test_maxflow_ragged_sharded_via_bucket_front_end():
    """Ragged queues (sizes NOT divisible by the shard count) shard via the
    inert-padding path and still bit-match the unsharded front end."""
    rng = np.random.default_rng(2)
    shapes = [(5, 5), (8, 8), (4, 7), (8, 8), (5, 5)]   # 5 instances
    probs = [GridProblem(*map(jnp.asarray, random_grid_problem(rng, h, w)))
             for h, w in shapes]
    for bucket in ("max", "pow2"):
        base = solve_maxflow_batch(probs, bucket=bucket)
        for s in SHARD_COUNTS:
            res = solve_maxflow_batch(probs, bucket=bucket,
                                      mesh=make_solver_mesh(s))
            for a, b in zip(res, base):
                _assert_trees_equal(a, b)


@multi
def test_assignment_ragged_sharded_via_bucket_front_end():
    ws = [np.random.default_rng(i).integers(-30, 71, (n, n))
          for i, n in enumerate([4, 9, 6, 9, 5])]        # ragged, odd count
    base = solve_assignment_batch(ws, bucket="max")
    for s in SHARD_COUNTS:
        res = solve_assignment_batch(ws, bucket="max",
                                     mesh=make_solver_mesh(s))
        for a, b in zip(res, base):
            _assert_trees_equal(a, b)


@multi
def test_sharded_batch_must_divide():
    probs = _grid_problems(3, 3, 6, 6)
    from repro.core.batch import stack_grid_problems
    with pytest.raises(ValueError, match="not divisible"):
        maxflow_grid_batch(stack_grid_problems(probs),
                           mesh=make_solver_mesh(2))
    ws = jnp.asarray(np.random.default_rng(0).integers(0, 9, (3, 5, 5)))
    with pytest.raises(ValueError, match="not divisible"):
        solve_assignment(ws, mesh=make_solver_mesh(2))
    with pytest.raises(ValueError, match="batched"):
        solve_assignment(ws[0], mesh=make_solver_mesh(2))


def test_solver_engine_matches_direct_front_end():
    """The serve path returns exactly what the direct batch calls return
    (runs at any device count; sharded when >1 device is available)."""
    mesh = make_solver_mesh() if N_DEV >= 2 else None
    engine = SolverEngine(mesh=mesh, bucket="max")
    rng = np.random.default_rng(7)
    probs = [GridProblem(*map(jnp.asarray, random_grid_problem(rng, h, w)))
             for h, w in [(6, 6), (4, 5), (6, 6)]]
    ws = [rng.integers(0, 50, (n, n)) for n in (5, 7)]

    tickets = [engine.submit("maxflow", p) for p in probs]
    tickets += [engine.submit("assignment", w) for w in ws]
    assert engine.pending() == 5
    out = engine.flush()
    assert engine.pending() == 0 and sorted(out) == tickets

    base_f = solve_maxflow_batch(probs, bucket="max", mesh=mesh)
    base_a = solve_assignment_batch(ws, bucket="max", mesh=mesh)
    for t, b in zip(tickets, base_f + base_a):
        _assert_trees_equal(out[t], b)


def test_solver_engine_rejects_malformed_at_submit():
    """Bad requests are refused BEFORE a ticket exists, so a queue can never
    hold an entry that would wedge flush(); good tickets are unaffected."""
    engine = SolverEngine()
    rng = np.random.default_rng(0)
    t = engine.submit("maxflow", 
        GridProblem(*map(jnp.asarray, random_grid_problem(rng, 4, 4))))
    with pytest.raises(ValueError, match="malformed assignment"):
        engine.submit("assignment", np.ones((3, 4)))       # non-square
    with pytest.raises(ValueError, match="malformed assignment"):
        engine.submit("assignment", np.ones((3, 3)))       # non-integer
    with pytest.raises(ValueError, match="malformed grid"):
        engine.submit("maxflow", GridProblem(
            jnp.zeros((4, 5, 5)), jnp.zeros((5, 4)), jnp.zeros((5, 4))))
    assert engine.pending() == 1
    out = engine.flush()                                # still solvable
    assert sorted(out) == [t] and engine.pending() == 0
