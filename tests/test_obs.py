"""Observability: span tracing, solver-loop telemetry, export surfaces.

The contract under test (repro.obs + the instrumentation it hooks into):

* TRACER — ``repro.obs.Tracer`` records spans lock-free from many
  threads at once, tracks per-thread nesting (parent ids), exports a
  plain event list and valid Chrome-trace JSON, and round-trips through
  ``save``/``load_trace``.
* LIFECYCLE RECONSTRUCTION — a traced ``AsyncSolverEngine`` session
  (closed-batch, refill, and sharded) yields, for EVERY resolved ticket,
  a complete ``submit -> queue-wait -> solve -> resolve`` chain with
  consistent, monotonic span boundaries; refill-admitted tickets carry
  ``trigger="refill"`` and a ``refill-admission`` span.
* CYCLE TELEMETRY — ``repro.core.solver_loop.cycle_events`` streams
  structured per-cycle events from BOTH the masked and compacted
  drivers, for all three solver kinds; ``trace_cycles`` stays a working
  back-compat shim.
* BIT-MATCH — tracing enabled vs disabled changes NOTHING about solver
  outputs (values and counters) on the masked, compacted, and refill
  paths. Telemetry observes; it never steers.
* EXPORT — ``prometheus_text`` renders every ``SchedulerMetrics``
  snapshot field (completeness enforced: unknown keys raise), and
  ``benchmarks.run --trace`` writes a valid Chrome-trace file plus a
  ``wall_s`` column in the CSV.
* HYGIENE — the instrumented non-shim serving paths run clean under
  ``-W error::DeprecationWarning``, and ``SchedulerMetrics.snapshot()``
  returns a deep copy.

Multi-device is emulated as in test_shard.py: CI also runs this file
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import json
import pathlib
import sys
import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.kinds as kinds_mod
from repro.core import (GridProblem, cycle_events, maxflow_grid_batch,
                        match_bipartite_batch, solve_assignment,
                        trace_cycles)
from repro.core.maxflow.ref import random_grid_problem
from repro.core.refill import RefillSolver
from repro.launch.mesh import make_solver_mesh
from repro.obs import (Tracer, current_tracer, load_trace, prometheus_text,
                       step_annotation, use_tracer)
from repro.serve.engine import SolverEngine
from repro.serve.metrics import Ewma, LatencyWindow, SchedulerMetrics
from repro.serve.scheduler import AsyncSolverEngine

pytestmark = pytest.mark.obs

N_DEV = len(jax.devices())
multi = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices; CI runs this file under the "
                      "forced 8-device flag")

WAIT_S = 120.0
LONG_DEADLINE_MS = 600_000.0

LIFECYCLE = {"submit", "queue-wait", "solve", "resolve"}


# ------------------------------------------------------------ helpers

def _grid_problems(seed, B, H, W):
    rng = np.random.default_rng(seed)
    return [GridProblem(*map(jnp.asarray, random_grid_problem(rng, H, W)))
            for _ in range(B)]


def _grid_batch(seed, B, H, W):
    rng = np.random.default_rng(seed)
    return GridProblem(
        jnp.asarray(rng.integers(0, 5, (B, 4, H, W)), jnp.float32),
        jnp.asarray(rng.integers(0, 6, (B, H, W)), jnp.float32),
        jnp.asarray(rng.integers(0, 6, (B, H, W)), jnp.float32))


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _ticket_chains(tracer: Tracer) -> dict:
    """Group lifecycle spans by their ``ticket`` attribute."""
    chains: dict = {}
    for s in tracer.spans():
        t = s.attrs.get("ticket")
        if t is not None:
            chains.setdefault(t, []).append(s)
    return chains


def _check_lifecycle(chains: dict, tickets) -> None:
    """Every ticket has a full, gap-consistent, monotonic span chain."""
    for t in tickets:
        assert t in chains, f"ticket {t} left no spans"
        by_name = {}
        for s in chains[t]:
            assert s.t0 <= s.t1, f"span {s.name} of ticket {t} runs backwards"
            by_name.setdefault(s.name, s)
        assert LIFECYCLE <= set(by_name), \
            f"ticket {t} missing stages: {LIFECYCLE - set(by_name)}"
        # submit ends where queue-wait begins; each later stage starts no
        # earlier than the previous one ended
        assert abs(by_name["submit"].t1 - by_name["queue-wait"].t0) < 1e-9
        assert by_name["queue-wait"].t1 <= by_name["solve"].t0 + 1e-9
        assert by_name["solve"].t1 <= by_name["resolve"].t0 + 1e-9


# ------------------------------------------------------------ tracer core

def test_span_nesting_tracks_parent_ids():
    tr = Tracer()
    with tr.span("outer", kind="maxflow"):
        with tr.span("inner", step=1):
            pass
        with tr.span("inner2"):
            pass
    with tr.span("top"):
        pass
    spans = {s.name: s for s in tr.spans()}
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert spans["inner2"].parent_id == spans["outer"].span_id
    assert spans["outer"].parent_id is None
    assert spans["top"].parent_id is None
    assert spans["outer"].attrs == {"kind": "maxflow"}
    # inner spans finish (and are appended) before their parent
    assert [s.name for s in tr.spans()] == ["inner", "inner2", "outer", "top"]
    ids = [s.span_id for s in tr.spans()]
    assert len(set(ids)) == len(ids)


def test_record_and_instant_spans():
    tr = Tracer()
    sid = tr.record("queue-wait", 10.0, 12.5, ticket=7)
    tr.instant("mark", cycle=3)
    qw, mark = tr.spans()
    assert (qw.name, qw.t0, qw.t1, qw.span_id) == ("queue-wait", 10.0, 12.5,
                                                   sid)
    assert qw.attrs == {"ticket": 7}
    assert mark.t0 == mark.t1 and mark.attrs == {"cycle": 3}
    tr.clear()
    assert tr.spans() == []


def test_chrome_export_structure():
    tr = Tracer()
    with tr.span("device-solve", kind="matching", bucket=[8, 8]):
        pass
    doc = tr.to_chrome()
    assert doc["displayTimeUnit"] == "ms"
    (ev,) = doc["traceEvents"]
    assert ev["ph"] == "X" and ev["name"] == "device-solve"
    assert ev["dur"] >= 0 and isinstance(ev["ts"], float)
    assert ev["args"]["kind"] == "matching"
    assert ev["args"]["bucket"] == [8, 8]
    assert "span_id" in ev["args"] and "parent_id" in ev["args"]
    json.dumps(doc)  # must be JSON-serializable as-is


def test_save_load_roundtrip(tmp_path):
    tr = Tracer()
    tr.record("solve", 1.0, 2.0, ticket=0)
    path = tmp_path / "trace.json"
    tr.save(path)
    events = load_trace(path)
    assert len(events) == 1 and events[0]["name"] == "solve"
    # the bare event-array form of the Chrome-trace spec loads too
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps(events))
    assert load_trace(bare) == events
    bad = tmp_path / "bad.json"
    bad.write_text('{"not": "a trace"}')
    with pytest.raises((ValueError, KeyError)):
        load_trace(bad)


def test_tracer_concurrent_recording():
    """Many threads record nested spans at once: nothing is lost, ids stay
    unique, and nesting never leaks across threads."""
    tr = Tracer()
    n_threads, n_spans = 8, 100
    barrier = threading.Barrier(n_threads)

    def worker(k):
        barrier.wait()
        for i in range(n_spans):
            with tr.span("outer", worker=k, i=i):
                with tr.span("inner", worker=k, i=i):
                    pass

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = tr.spans()
    assert len(spans) == n_threads * n_spans * 2
    ids = {s.span_id for s in spans}
    assert len(ids) == len(spans)
    outer_by_tid = {}
    for s in spans:
        if s.name == "outer":
            outer_by_tid.setdefault(s.tid, set()).add(s.span_id)
    for s in spans:
        if s.name == "inner":
            assert s.parent_id in outer_by_tid[s.tid], \
                "inner span parented across threads"


def test_ambient_tracer_contextvar():
    assert current_tracer() is None
    tr = Tracer()
    with use_tracer(tr) as got:
        assert got is tr and current_tracer() is tr
        with use_tracer(None):
            assert current_tracer() is None
        assert current_tracer() is tr
    assert current_tracer() is None


def test_step_annotation_is_harmless_without_profiler():
    with step_annotation("solve:maxflow", bucket="8x8"):
        x = jnp.zeros((2, 2)) + 1
    assert float(x.sum()) == 4.0


# ------------------------------------------------------- cycle telemetry

def test_cycle_events_masked_maxflow_bitmatch():
    prob = _grid_batch(0, 5, 6, 6)
    base = maxflow_grid_batch(prob)
    evs = []
    with cycle_events(evs.append, masked=True, detail=True):
        traced = maxflow_grid_batch(prob)
    assert evs, "masked driver emitted no cycle events"
    assert all(e.driver == "masked" for e in evs)
    assert [e.cycle for e in evs] == list(range(len(evs)))
    lives = [e.n_live for e in evs]
    assert lives == sorted(lives, reverse=True), \
        f"masked live counts not monotone: {lives}"
    assert lives[0] == 5
    assert all(e.gathered == 5 for e in evs), \
        "masked driver dispatches the full batch every cycle"
    assert all(e.heur_total is not None and e.heur_total >= 0 for e in evs)
    rt = [e.rounds_total for e in evs]
    assert rt == sorted(rt)
    _assert_trees_equal(base, traced)


def test_cycle_events_compacted_maxflow_bitmatch():
    prob = _grid_batch(1, 6, 6, 6)
    base = maxflow_grid_batch(prob, compact=True)
    evs = []
    with cycle_events(evs.append, detail=True):
        traced = maxflow_grid_batch(prob, compact=True)
    assert evs and all(e.driver == "compacted" for e in evs)
    assert [e.cycle for e in evs] == list(range(len(evs)))
    lives = [e.n_live for e in evs]
    assert lives == sorted(lives, reverse=True)
    # compaction gathers pow2 buckets: the dispatch width tracks, but
    # never undercuts, the live count
    assert all(e.gathered >= e.n_live for e in evs)
    assert all(e.heur_total is not None for e in evs)
    _assert_trees_equal(base, traced)


def test_cycle_events_masked_needs_optin():
    """Without masked=True the masked driver stays one fused dispatch and
    emits nothing (jit caches must never depend on ambient hooks)."""
    prob = _grid_batch(2, 3, 6, 6)
    evs = []
    with cycle_events(evs.append):              # compacted-only by default
        maxflow_grid_batch(prob)
    assert evs == []


def test_cycle_events_all_kinds_bitmatch():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.integers(0, 9, (4, 5, 5)), jnp.int32)
    adj = jnp.asarray(rng.random((4, 6, 6)) < 0.4)
    for solve in (lambda: solve_assignment(w),
                  lambda: match_bipartite_batch(adj)):
        base = solve()
        evs = []
        with cycle_events(evs.append, masked=True):
            traced = solve()
        assert evs and evs[0].driver == "masked"
        assert evs[0].heur_total is None        # detail=False skips the fetch
        _assert_trees_equal(base, traced)
        evs_c = []
        with cycle_events(evs_c.append):
            pass
        assert evs_c == []                      # hook uninstalled on exit


def test_trace_cycles_shim_still_works():
    prob = _grid_batch(4, 5, 6, 6)
    calls = []
    with trace_cycles(lambda c, n: calls.append((c, n))):
        maxflow_grid_batch(prob, compact=True)
    assert calls and calls[0][0] == 0 and calls[0][1] == 5
    assert all(isinstance(c, int) and isinstance(n, int) for c, n in calls)
    n_installed = len(calls)
    maxflow_grid_batch(prob, compact=True)
    assert len(calls) == n_installed, "shim hook leaked past its context"


def test_refill_session_bitmatch_and_spans():
    rng = np.random.default_rng(5)
    ws = [rng.integers(0, 50, (5, 5)) for _ in range(6)]
    queue = list(ws[3:])

    def admit(n_free):
        out, queue[:] = queue[:n_free], queue[n_free:]
        return out

    base = RefillSolver("assignment", shape=(5,), capacity=3).run(
        ws[:3], admit=admit)
    queue[:] = list(ws[3:])
    tr = Tracer()
    traced = RefillSolver("assignment", shape=(5,), capacity=3,
                          tracer=tr).run(ws[:3], admit=admit)
    assert set(base) == set(traced) == set(range(6))
    for i in base:
        _assert_trees_equal(base[i], traced[i])
    names = [s.name for s in tr.spans()]
    assert names.count("bucket/pad") == 6       # one intake span per payload
    solve = [s for s in tr.spans() if s.name == "device-solve"]
    assert len(solve) == 1
    assert solve[0].attrs["driver"] == "refill"
    assert solve[0].attrs["kind"] == "assignment"
    assert solve[0].attrs["capacity"] == 3


# --------------------------------------------- serving: lifecycle spans

@pytest.mark.serve
def test_async_lifecycle_reconstructs_every_ticket():
    """The acceptance trace: a refill-enabled async session leaves a full
    submit/queue-wait/solve/resolve chain for every resolved ticket."""
    tr = Tracer()
    probs = _grid_problems(6, 9, 6, 6)
    with use_tracer(tr):
        eng = AsyncSolverEngine(max_batch=4, max_delay_ms=30.0, refill=True)
    assert eng.tracer is tr                     # captured from the ambient var
    with eng:
        futs = [eng.submit("maxflow", p) for p in probs]
        results = [f.result(timeout=WAIT_S) for f in futs]
    assert all(r is not None for r in results)
    chains = _ticket_chains(tr)
    _check_lifecycle(chains, range(len(probs)))
    for t, spans in chains.items():
        for s in spans:
            if s.name == "queue-wait":
                assert s.attrs["trigger"] in {"size", "deadline", "manual",
                                              "drain", "refill"}
            if s.name == "solve":
                assert s.attrs["driver"] in {"masked", "compacted", "refill",
                                             "isolated"}
            assert s.attrs["kind"] == "maxflow"
    other = {s.name for s in tr.spans() if "ticket" not in s.attrs}
    assert {"bucket/pad", "device-solve"} <= other
    # the whole trace exports cleanly
    json.dumps(tr.to_chrome())
    assert prometheus_text(eng.metrics).startswith("# HELP repro_")


@pytest.mark.serve
@multi
def test_async_lifecycle_sharded_two_devices():
    mesh = make_solver_mesh(2)
    tr = Tracer()
    probs = _grid_problems(7, 8, 6, 6)
    with AsyncSolverEngine(max_batch=4, max_delay_ms=30.0, refill=True,
                           mesh=mesh, tracer=tr) as eng:
        futs = [eng.submit("maxflow", p) for p in probs]
        for f in futs:
            assert f.result(timeout=WAIT_S) is not None
    _check_lifecycle(_ticket_chains(tr), range(len(probs)))


def _gated_refill_factory(real_kind, started, gate):
    """Wrap a kind's refill runtime so the FIRST finalize blocks on
    ``gate`` (signalling ``started``) — pinning the session mid-solve so
    requests submitted meanwhile can only resolve via admission (the
    deterministic-admission pattern of tests/test_refill.py)."""
    def factory(**kw):
        rt = real_kind.refill(**kw)

        def finalize(problems, st1, r):
            if not started.is_set():
                started.set()
                assert gate.wait(timeout=WAIT_S), "test gate never opened"
            return rt.finalize(problems, st1, r)

        return rt._replace(finalize=finalize)
    return factory


@pytest.mark.serve
def test_refill_admission_spans(monkeypatch):
    """Mid-solve-admitted tickets trace ``trigger="refill"`` queue-waits,
    refill-driver solve spans, and a ``refill-admission`` span naming
    them."""
    started, gate = threading.Event(), threading.Event()
    real = kinds_mod.get_kind("assignment")
    monkeypatch.setitem(
        kinds_mod._REGISTRY, "assignment",
        real._replace(refill=_gated_refill_factory(real, started, gate)))
    rng = np.random.default_rng(8)
    ws = [rng.integers(0, 50, (5, 5)) for _ in range(4)]
    tr = Tracer()
    with AsyncSolverEngine(max_batch=4, max_delay_ms=LONG_DEADLINE_MS,
                           refill=True, tracer=tr) as eng:
        seed = eng.submit("assignment", ws[0])
        eng.flush_now()                          # open the session
        assert started.wait(timeout=WAIT_S), "session never reached finalize"
        futs = [eng.submit("assignment", w) for w in ws[1:]]
        gate.set()
        assert seed.result(timeout=WAIT_S) is not None
        for f in futs:
            assert f.result(timeout=WAIT_S) is not None
    chains = _ticket_chains(tr)
    _check_lifecycle(chains, range(4))
    admitted = set()
    for t, spans in chains.items():
        for s in spans:
            if s.name == "queue-wait" and s.attrs["trigger"] == "refill":
                admitted.add(t)
            if s.name == "solve" and t != 0:
                assert s.attrs["driver"] == "refill"
    assert admitted == {1, 2, 3}, \
        f"expected tickets 1-3 admitted mid-solve, got {admitted}"
    adm = [s for s in tr.spans() if s.name == "refill-admission"]
    assert adm, "no refill-admission span recorded"
    assert set().union(*(s.attrs["tickets"] for s in adm)) == {1, 2, 3}
    for s in adm:
        assert s.attrs["kind"] == "assignment"
        assert 1 <= s.attrs["admitted"] <= s.attrs["n_free"]


@pytest.mark.serve
def test_async_serving_bitmatch_traced_vs_untraced():
    """Tracing observes the serving path without steering it: the same
    request stream yields identical results with and without a tracer."""
    probs = _grid_problems(9, 6, 6, 6)

    def run(tracer):
        with AsyncSolverEngine(max_batch=3, max_delay_ms=30.0, refill=True,
                               tracer=tracer) as eng:
            futs = [eng.submit("maxflow", p) for p in probs]
            return [f.result(timeout=WAIT_S) for f in futs]

    tr = Tracer()
    for plain, traced in zip(run(None), run(tr)):
        _assert_trees_equal(plain, traced)
    assert tr.spans(), "traced run recorded nothing"


@pytest.mark.serve
def test_instrumented_paths_deprecationwarning_free():
    """The non-shim engine/scheduler paths run clean under
    ``-W error::DeprecationWarning`` even while traced."""
    tr = Tracer()
    probs = _grid_problems(10, 3, 6, 6)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        blocking = SolverEngine(tracer=tr)
        tickets = [blocking.submit("maxflow", p) for p in probs]
        res = blocking.flush()
        assert set(tickets) <= set(res)
        with AsyncSolverEngine(max_batch=3, max_delay_ms=30.0,
                               tracer=tr) as eng:
            futs = [eng.submit("maxflow", p) for p in probs]
            for f in futs:
                assert f.result(timeout=WAIT_S) is not None
        prometheus_text(eng.metrics)
        json.dumps(tr.to_chrome())


# ----------------------------------------------------- metrics hygiene

def test_latency_window_empty_percentiles_are_none():
    win = LatencyWindow()
    assert win.percentiles() == {"p50": None, "p99": None}
    assert len(win) == 0


def test_latency_window_single_sample_percentiles_coincide():
    win = LatencyWindow()
    win.record(42.0)
    p = win.percentiles()
    assert p["p50"] == p["p99"] == 42.0


def test_ewma_alpha_bounds():
    for alpha in (0.0, -0.25, 1.5):
        with pytest.raises(ValueError, match="alpha"):
            Ewma(alpha=alpha)
    last_only = Ewma(alpha=1.0)                 # boundary: tracks the last x
    last_only.update(3.0)
    last_only.update(7.0)
    assert last_only.value == 7.0
    assert Ewma().value is None


def test_metrics_concurrent_hammer():
    """Racing recorders from many threads lose nothing: every counter
    lands exactly."""
    m = SchedulerMetrics()
    n_threads, n_iter = 8, 200
    barrier = threading.Barrier(n_threads)

    def worker(k):
        barrier.wait()
        for i in range(n_iter):
            m.record_submit(queue_depth=i)
            m.record_flush("size", queue_depth=0)
            m.record_dispatch("maxflow", compact=bool(i % 2), spread=0.1,
                              occupancy=0.5, rounds=4.0, heuristics=1.0)
            m.record_done(1.0)
            m.record_live_trace(i, n_live=2)
            m.record_refill_session("maxflow")
            m.record_refill_admit("maxflow", 2)
            m.record_refill_cycle("maxflow", 0.75)
            m.record_cancelled()

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = n_threads * n_iter
    snap = m.snapshot()
    assert snap["tickets"] == {"submitted": total, "completed": total,
                               "cancelled": total}
    assert snap["flushes_by_trigger"] == {"size": total}
    assert snap["dispatches"] == {"maxflow:masked": total // 2,
                                  "maxflow:compacted": total // 2}
    assert snap["compact_cycles"] == total
    assert snap["compact_live_mean"] == 2.0
    assert snap["refill"]["sessions"] == {"maxflow": total}
    assert snap["refill"]["admitted"] == {"maxflow": 2 * total}
    assert snap["refill"]["utilization"] == pytest.approx(0.75)
    assert snap["latency_ms"]["p50"] == 1.0


def test_snapshot_is_a_deep_copy():
    m = SchedulerMetrics()
    m.record_submit(queue_depth=3)
    m.record_refill_admit("maxflow", 2)
    m.record_dispatch("maxflow", compact=False, spread=0.5, occupancy=1.0)
    snap = m.snapshot()
    snap["tickets"]["submitted"] = 10 ** 6
    snap["refill"]["admitted"]["maxflow"] = -1
    snap["refill"]["sessions"]["injected"] = 99
    snap["spread_ewma"]["maxflow"] = -42.0
    fresh = m.snapshot()
    assert fresh["tickets"]["submitted"] == 1
    assert fresh["refill"]["admitted"] == {"maxflow": 2}
    assert "injected" not in fresh["refill"]["sessions"]
    assert fresh["spread_ewma"]["maxflow"] == 0.5


# ------------------------------------------------- prometheus exposition

# every snapshot key maps to the exposition family its renderer emits; the
# two-way assertion below forces this table (and the renderer registry) to
# grow whenever the snapshot does
FAMILY_OF = {
    "queue_depth": "repro_queue_depth",
    "tickets": "repro_tickets_total",
    "flushes_by_trigger": "repro_flushes_total",
    "dispatches": "repro_dispatches_total",
    "latency_ms": "repro_ticket_latency_ms",
    "latency_samples": "repro_ticket_latency_samples",
    "compact_cycles": "repro_compact_cycles_total",
    "compact_live_mean": "repro_compact_live_mean",
    "refill": "repro_refill_sessions_total",
    "warm": "repro_warm_cache_lookups_total",
    "spread_ewma": "repro_spread_ewma",
    "occupancy_ewma": "repro_occupancy_ewma",
    "rounds_ewma": "repro_rounds_ewma",
    "heuristics_ewma": "repro_heuristics_ewma",
}


def _populated_metrics() -> SchedulerMetrics:
    m = SchedulerMetrics()
    m.record_submit(queue_depth=2)
    m.record_flush("deadline", queue_depth=0)
    m.record_dispatch("maxflow", compact=True, spread=0.3, occupancy=0.9,
                      rounds=7.0, heuristics=2.0)
    m.record_done(12.5)
    m.record_live_trace(0, n_live=4)
    m.record_refill_session("maxflow")
    m.record_refill_admit("maxflow", 3)
    m.record_refill_cycle("maxflow", 0.5)
    m.record_cache_lookup(True)
    m.record_cache_lookup(False)
    m.record_warm("maxflow", 2, 6, rounds_saved=3.0)
    return m


def test_prometheus_renders_every_snapshot_field():
    m = _populated_metrics()
    snap = m.snapshot()
    assert set(snap) == set(FAMILY_OF), (
        "snapshot keys and the exposition-family table diverged — teach "
        "repro.obs.export (and this test) about the new field")
    text = prometheus_text(m)
    for key, family in FAMILY_OF.items():
        assert f"# HELP {family} " in text, f"{key} not rendered"
        assert f"# TYPE {family} " in text
    # spot-check labels and values
    assert 'repro_tickets_total{status="submitted"} 1' in text
    assert 'repro_flushes_total{trigger="deadline"} 1' in text
    assert 'repro_dispatches_total{kind="maxflow",driver="compacted"} 1' \
        in text
    assert 'repro_ticket_latency_ms{quantile="0.5"} 12.5' in text
    assert 'repro_refill_admitted_total{kind="maxflow"} 3' in text
    assert 'repro_warm_cache_lookups_total{result="hit"} 1' in text
    assert 'repro_warm_solves_total{init="warm"} 2' in text
    assert 'repro_warm_fraction 0.25' in text
    assert 'repro_warm_rounds_saved_ewma{kind="maxflow"} 3' in text
    assert text.endswith("\n")


def test_prometheus_accepts_snapshot_dict_and_skips_none():
    text = prometheus_text(SchedulerMetrics().snapshot())
    # empty window / unobserved EWMAs: family headers stay, no samples
    assert "# HELP repro_ticket_latency_ms " in text
    assert "repro_ticket_latency_ms{" not in text
    assert "repro_compact_live_mean\n" not in text.replace("gauge\n", "")
    assert "repro_queue_depth 0" in text


def test_prometheus_unknown_snapshot_key_raises():
    snap = SchedulerMetrics().snapshot()
    snap["brand_new_metric"] = 1
    with pytest.raises(KeyError, match="brand_new_metric"):
        prometheus_text(snap)


# ------------------------------------------------------ bench harness

def _bench_run_module():
    root = pathlib.Path(__file__).resolve().parents[1]
    if str(root) not in sys.path:               # direct-file invocation
        sys.path.insert(0, str(root))
    import benchmarks.run as bench_run
    return bench_run


def _fake_bench(rows, repeats=2):
    eng = SolverEngine()                        # captures the ambient tracer
    adj = np.ones((3, 3), dtype=bool)
    ticket = eng.submit("matching", adj)
    res = eng.flush()[ticket]
    rows.append(("fake_matching", 1.5, int(res.rounds), "card=3"))
    rows.append(("fake_legacy", 2.5, "derived=x"))  # legacy 3-tuple row


def test_bench_wall_column_and_trace(tmp_path, monkeypatch, capsys):
    bench_run = _bench_run_module()
    from repro.core.kinds import registered_kinds
    monkeypatch.setattr(bench_run, "BENCHES", {"fake": _fake_bench})
    monkeypatch.setattr(bench_run, "KIND_BENCHES",
                        {k: "fake" for k in registered_kinds()})
    csv, trace = tmp_path / "bench.csv", tmp_path / "trace.json"
    bench_run.main(["fake", "--csv", str(csv), "--trace", str(trace)])
    out = capsys.readouterr().out
    lines = csv.read_text().splitlines()
    assert lines[0] == "name,us_per_call,rounds,wall_s,derived"
    assert out.splitlines()[0] == lines[0]      # stdout carries the same CSV
    r1 = lines[1].split(",")
    assert r1[0] == "fake_matching" and r1[2] != ""
    assert float(r1[3]) >= 0.0
    r2 = lines[2].split(",")
    assert r2[0] == "fake_legacy" and r2[2] == ""   # rounds stays empty
    assert float(r2[3]) >= 0.0 and r2[4] == "derived=x"
    events = load_trace(trace)
    names = {e["name"] for e in events}
    # the engine built inside the bench captured the ambient tracer
    assert {"bench", "bucket/pad", "device-solve"} <= names
    (bench_ev,) = [e for e in events if e["name"] == "bench"]
    assert bench_ev["args"]["bench"] == "fake"


def test_bench_csv_without_trace_flag(tmp_path, monkeypatch, capsys):
    bench_run = _bench_run_module()
    from repro.core.kinds import registered_kinds
    monkeypatch.setattr(bench_run, "BENCHES", {"fake": _fake_bench})
    monkeypatch.setattr(bench_run, "KIND_BENCHES",
                        {k: "fake" for k in registered_kinds()})
    csv = tmp_path / "bench.csv"
    bench_run.main(["fake", "--csv", str(csv)])
    capsys.readouterr()
    lines = csv.read_text().splitlines()
    assert lines[0] == "name,us_per_call,rounds,wall_s,derived"
    assert len(lines) == 3 and all(len(l.split(",")) == 5
                                   for l in lines[1:])
