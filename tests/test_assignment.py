"""Cost-scaling assignment vs Hungarian oracle + ε-optimality (paper §5)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-hypothesis shim

from repro.core.assignment.cost_scaling import solve_assignment
from repro.core.assignment.ref import (eps_optimal, optimal_weight,
                                       optimal_weight_bruteforce)


@pytest.mark.parametrize("method", ["pushrelabel", "auction"])
@pytest.mark.parametrize("seed", range(4))
def test_assignment_optimal(method, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 24))
    w = rng.integers(0, 101, size=(n, n))
    res = solve_assignment(jnp.asarray(w), method=method)
    assert bool(res.converged)
    assert int(res.weight) == optimal_weight(w)
    # a perfect matching (permutation)
    assert sorted(np.asarray(res.col_of_row).tolist()) == list(range(n))


def test_assignment_negative_and_tiny():
    rng = np.random.default_rng(9)
    w = rng.integers(-50, 51, size=(6, 6))
    res = solve_assignment(jnp.asarray(w))
    assert int(res.weight) == optimal_weight(w)
    assert int(res.weight) == optimal_weight_bruteforce(np.asarray(w))
    w1 = np.asarray([[7]])
    assert int(solve_assignment(jnp.asarray(w1)).weight) == 7


@pytest.mark.parametrize("kw", [
    dict(use_price_update=False, use_arc_fixing=False),
    dict(use_price_update=True, use_arc_fixing=False),
    dict(use_price_update=False, use_arc_fixing=True),
    dict(method="pushrelabel", rounds_per_heuristic=4),
])
def test_assignment_heuristic_ablations(kw):
    rng = np.random.default_rng(1)
    w = rng.integers(0, 101, size=(12, 12))
    res = solve_assignment(jnp.asarray(w), **kw)
    assert int(res.weight) == optimal_weight(w)


def test_assignment_pallas_backend():
    rng = np.random.default_rng(2)
    w = rng.integers(0, 101, size=(16, 16))
    for method in ["pushrelabel", "auction"]:
        res = solve_assignment(jnp.asarray(w), method=method,
                               backend="pallas")
        assert int(res.weight) == optimal_weight(w)


def test_paper_operating_point():
    """Paper §6: complete bipartite, |X|=|Y|<=30, costs <= 100."""
    rng = np.random.default_rng(2011)
    w = rng.integers(0, 101, size=(30, 30))
    res = solve_assignment(jnp.asarray(w), method="pushrelabel")
    assert int(res.weight) == optimal_weight(w)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 12),
       st.sampled_from(["pushrelabel", "auction"]))
def test_assignment_property(seed, n, method):
    """Property: optimality + the auction invariant that prices of Y only
    decrease (paper Lemma 5.2 in Goldberg price coordinates)."""
    rng = np.random.default_rng(seed)
    w = rng.integers(0, 64, size=(n, n))
    res = solve_assignment(jnp.asarray(w), method=method)
    assert bool(res.converged)
    assert int(res.weight) == optimal_weight(w)
    # final pseudoflow is 1-optimal wrt final prices (scaled costs)
    F = np.zeros((n, n), np.int32)
    F[np.arange(n), np.asarray(res.col_of_row)] = 1
    assert eps_optimal(w, F, np.asarray(res.p_x), np.asarray(res.p_y),
                       eps=1)
