"""Flow-based MoE routing: feasibility, balance, optimality (integration)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-hypothesis shim
from scipy.optimize import linear_sum_assignment

from repro.core.routing import auction_route, exact_route, topk_route


def _scores(seed, T, E):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(T, E)).astype(np.float32))


def test_exact_route_is_optimal():
    T, E = 64, 8
    cap = T // E
    s = _scores(0, T, E)
    w = np.repeat(np.asarray(s), cap, axis=1)
    r_, c_ = linear_sum_assignment(w, maximize=True)
    opt = w[r_, c_].sum()
    r = exact_route(s, cap)
    val = float((np.asarray(s) * np.asarray(r.dispatch)).sum())
    assert abs(val - opt) < 1e-3
    assert int(np.asarray(r.dispatch).sum()) == T          # zero drops


def test_auction_route_beats_topk_on_drops():
    T, E, k = 128, 8, 1
    cap = T // E
    s = _scores(1, T, E)
    rt = topk_route(s, k, cap)
    ra = auction_route(s, k, cap, n_iters=16)
    dropped_topk = T - int(np.asarray(rt.dispatch).sum())
    dropped_auct = T - int(np.asarray(ra.dispatch).sum())
    assert dropped_auct <= dropped_topk
    assert dropped_auct == 0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 8), st.integers(1, 3),
       st.integers(8, 64))
def test_routing_feasibility_property(seed, E, k, T):
    """Property: never exceed per-token k nor per-expert capacity."""
    k = min(k, E)
    cap = max(1, int(T * k / E * 1.25))
    s = _scores(seed, T, E)
    for r in (topk_route(s, k, cap), auction_route(s, k, cap)):
        d = np.asarray(r.dispatch)
        assert d.sum(axis=0).max() <= cap
        assert d.sum(axis=1).max() <= k
        c = np.asarray(r.combine)
        assert (c[~d] == 0).all()
        assert np.isfinite(c).all()


def test_flow_router_better_balance():
    """Skewed logits: flow routing caps hot experts, topk truncates."""
    rng = np.random.default_rng(5)
    T, E, k = 256, 8, 2
    s = rng.normal(size=(T, E)).astype(np.float32)
    s[:, 0] += 3.0                      # everyone loves expert 0
    cap = int(T * k / E * 1.25)
    rt = topk_route(jnp.asarray(s), k, cap)
    ra = auction_route(jnp.asarray(s), k, cap, n_iters=16)
    routed_t = int(np.asarray(rt.dispatch).sum())
    routed_a = int(np.asarray(ra.dispatch).sum())
    assert routed_a >= routed_t          # auction re-routes the overflow


def test_transportation_exact():
    """solve_transportation: feasible + matches scipy on slot expansion."""
    import numpy as np
    from repro.core.routing import solve_transportation
    rng = np.random.default_rng(0)
    n_x, n_y = 12, 4
    w = rng.integers(0, 50, (n_x, n_y))
    supply = np.full(n_x, 2)            # k=2 per token
    capacity = np.full(n_y, 8)          # expert capacity
    flow, res = solve_transportation(jnp.asarray(w), supply, capacity)
    f = np.asarray(flow)
    assert (f.sum(1) == supply).all()
    assert (f.sum(0) <= capacity).all()
    got = (f * w).sum()
    # oracle: scipy on the same slot expansion
    rows = np.repeat(np.arange(n_x), supply)
    cols = np.repeat(np.arange(n_y), capacity)
    big = np.zeros((capacity.sum(), capacity.sum()))
    big[:len(rows), :] = w[rows][:, cols]
    r_, c_ = linear_sum_assignment(big, maximize=True)
    assert got == int(big[r_, c_].sum())
