"""Substrate tests: SSD scan, optimizer, data pipeline, checkpointing, FT."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-hypothesis shim

from repro.data.pipeline import DataConfig, host_batch, rows_batch
from repro.models.mamba import _ssd_chunked
from repro.optim.adamw import (AdamWConfig, _dequantize, _quantize,
                               apply_updates, init_opt_state, lr_schedule)
from repro.checkpoint import store


# ---------------------------------------------------------------- SSD scan
def _naive_ssd(xh, dt, A, Bm, Cm):
    B, S, H, P = xh.shape
    h = np.zeros((B, H, P, Bm.shape[-1]))
    ys = []
    for t in range(S):
        a = np.exp(-np.asarray(dt[:, t]) * np.asarray(A)[None])
        upd = np.einsum("bh,bn,bhp->bhpn", np.asarray(dt[:, t]),
                        np.asarray(Bm[:, t]), np.asarray(xh[:, t]))
        h = h * a[:, :, None, None] + upd
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(Cm[:, t]), h))
    return np.stack(ys, 1), h


@pytest.mark.parametrize("chunk", [4, 16, 32])
def test_ssd_chunked_vs_naive(chunk):
    rng = np.random.default_rng(0)
    B, S, H, P, N = 2, 32, 3, 4, 5
    xh = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, S, H)).astype(np.float32))
    A = jnp.asarray(rng.uniform(0.5, 2, (H,)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    y, hT = _ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y_ref, h_ref = _naive_ssd(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(hT), h_ref, rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------- optimizer
def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr_peak=0.1, lr_min=0.01, warmup_steps=2,
                      decay_steps=100, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(cfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, m = apply_updates(cfg, params, g, state)
    assert float(loss(params)) < 1e-2


def test_adamw_quantized_moments_close_to_exact():
    cfg_q = AdamWConfig(lr_peak=0.05, warmup_steps=1, decay_steps=50,
                        weight_decay=0.0, quantize_moments=True)
    cfg_e = AdamWConfig(lr_peak=0.05, warmup_steps=1, decay_steps=50,
                        weight_decay=0.0)
    rng = np.random.default_rng(0)
    w0 = jnp.asarray(rng.normal(size=(4, 512)).astype(np.float32))
    tgt = jnp.asarray(rng.normal(size=(4, 512)).astype(np.float32))
    loss = lambda p: jnp.mean((p["w"] - tgt) ** 2)
    outs = []
    for cfg in (cfg_q, cfg_e):
        params = {"w": w0}
        state = init_opt_state(cfg, params)
        for _ in range(30):
            g = jax.grad(loss)(params)
            params, state, _ = apply_updates(cfg, params, g, state)
        outs.append(float(loss(params)))
    assert abs(outs[0] - outs[1]) < 0.15 * (abs(outs[1]) + 1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 4), st.integers(1, 700))
def test_quantize_roundtrip_property(seed, rows, cols):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32)) * 10
    q = _quantize(x)
    back = _dequantize(q, x.shape)
    scale = float(jnp.max(jnp.abs(x))) + 1e-9
    assert float(jnp.max(jnp.abs(back - x))) <= scale / 127 + 1e-6


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10,
                      decay_steps=100)
    assert float(lr_schedule(cfg, 0)) < float(lr_schedule(cfg, 9))
    assert abs(float(lr_schedule(cfg, 10)) - 1e-3) < 1e-4
    assert float(lr_schedule(cfg, 99)) < 2e-4


# ---------------------------------------------------------------- pipeline
def test_pipeline_deterministic_and_elastic():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=3)
    a = host_batch(cfg, step=5, shard=0, n_shards=1)
    # re-partitioned into 2 shards: identical rows
    b0 = host_batch(cfg, step=5, shard=0, n_shards=2)
    b1 = host_batch(cfg, step=5, shard=1, n_shards=2)
    np.testing.assert_array_equal(
        a["tokens"], np.concatenate([b0["tokens"], b1["tokens"]]))
    # different steps differ
    c = host_batch(cfg, step=6, shard=0, n_shards=1)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < 1000 and a["tokens"].min() >= 0


def test_pipeline_has_learnable_structure():
    cfg = DataConfig(vocab=100, seq_len=128, global_batch=64, seed=0,
                     copy_prob=1.0)
    b = rows_batch(cfg, 0, 0, 64)
    # copied spans => some positions are exactly predictable
    eq = (b["tokens"][:, 1:] == b["tokens"][:, :-1]).mean()
    assert 0 <= eq < 1.0


# ------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip_and_resume(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.int32(7)}}
    store.save(str(tmp_path), 10, tree)
    store.save(str(tmp_path), 20, jax.tree.map(lambda x: x + 1, tree))
    assert store.latest_step(str(tmp_path)) == 20
    back = store.restore(str(tmp_path), 20, tree)
    np.testing.assert_allclose(np.asarray(back["a"]),
                               np.asarray(tree["a"]) + 1)
    assert int(back["b"]["c"]) == 8


def test_checkpoint_gc(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in range(5):
        store.save(str(tmp_path), s, tree, keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2 and kept[-1] == "step_00000004"


def test_checkpoint_partial_write_invisible(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    store.save(str(tmp_path), 1, tree)
    # a torn checkpoint: directory without manifest
    os.makedirs(tmp_path / "step_00000002")
    assert store.latest_step(str(tmp_path)) == 1


# ---------------------------------------------------------------- watchdog
def test_step_watchdog_flags_outlier():
    from repro.runtime.ft import StepWatchdog
    wd = StepWatchdog(threshold_x=2.0)
    import time as _t
    for i in range(12):
        wd.start()
        wd.times.append(0.01)   # synthetic fast steps
        wd.times.pop(0) if len(wd.times) > wd.window else None
    wd.times = [0.01] * 20
    wd._t0 = 0
    import time
    orig = time.monotonic
    time.monotonic = lambda: 0.05       # 5x median
    try:
        assert wd.stop(step=99) is True
    finally:
        time.monotonic = orig
