"""Pallas flash-attention kernel vs oracle: shape/dtype/GQA sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_attention_ref


@pytest.mark.parametrize("dims,blocks,causal", [
    ((2, 64, 4, 2, 16, 16), (16, 32), True),
    ((1, 128, 6, 3, 32, 16), (64, 32), False),
    ((2, 256, 8, 8, 64, 64), (128, 128), True),
    ((1, 64, 4, 1, 16, 8), (64, 64), True),     # MQA
    ((1, 512, 2, 2, 32, 32), (256, 512), True), # single k block row
])
def test_flash_kernel_sweep(dims, blocks, causal):
    B, S, H, KV, dh, dv = dims
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, dv)).astype(np.float32))
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=blocks[0],
                              block_k=blocks[1], interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_kernel_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.bfloat16)
    out = flash_attention_fwd(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)
