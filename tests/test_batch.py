"""Batched multi-instance solver engine: bit-match vs looped single solves.

The contract under test (repro.core.batch + the batch-polymorphic solvers):
a batched dispatch is EXACTLY a stack of single-instance solves — same flow
values, same cuts, same matchings, same prices, and same per-instance
round/push/relabel counters — because converged instances are frozen by
liveness masks, not blocked on the rest of the batch. All capacities/weights
are integers, so float sums are exact and equality is bitwise.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.assignment.cost_scaling import solve_assignment
from repro.core.assignment.ref import optimal_weight
from repro.core.batch import (inert_grid_problem, pad_cost_matrix,
                              pad_grid_problem, solve_assignment_batch,
                              solve_maxflow_batch, stack_grid_problems)
from repro.core.maxflow.grid import (GridProblem, check_no_violations,
                                     maxflow_grid, maxflow_grid_batch)
from repro.core.maxflow.ref import maxflow_grid_ref, random_grid_problem
from repro.core.routing import auction_route, topk_route


def _grid_problems(seed, B, H, W):
    rng = np.random.default_rng(seed)
    return [GridProblem(*map(jnp.asarray, random_grid_problem(rng, H, W)))
            for _ in range(B)]


@pytest.mark.parametrize("backend", ["xla", "multipush", "pallas"])
def test_maxflow_batch_bitmatches_loop(backend):
    probs = _grid_problems(0, 5, 8, 8)
    batch = stack_grid_problems(probs)
    rb = maxflow_grid_batch(batch, backend=backend)
    for b, p in enumerate(probs):
        rs = maxflow_grid(p, backend=backend)
        assert float(rb.flow[b]) == float(rs.flow)
        assert int(rb.rounds[b]) == int(rs.rounds)
        assert bool(rb.converged[b]) == bool(rs.converged)
        np.testing.assert_array_equal(np.asarray(rb.cut[b]),
                                      np.asarray(rs.cut))
        np.testing.assert_array_equal(np.asarray(rb.state.e[b]),
                                      np.asarray(rs.state.e))
        np.testing.assert_array_equal(np.asarray(rb.state.h[b]),
                                      np.asarray(rs.state.h))
        np.testing.assert_array_equal(np.asarray(rb.state.cap[b]),
                                      np.asarray(rs.state.cap))


@pytest.mark.parametrize("B", [3, 4])  # B=4 would alias the (4,...) layout
def test_check_no_violations_on_batched_state(B):
    rb = maxflow_grid_batch(stack_grid_problems(_grid_problems(4, B, 6, 6)))
    ok = check_no_violations(rb.state)
    assert ok.shape == (B,) and bool(jnp.all(ok))


def test_maxflow_batch_matches_scipy_oracle():
    probs = _grid_problems(1, 4, 6, 7)
    rb = maxflow_grid_batch(stack_grid_problems(probs))
    for b, p in enumerate(probs):
        ref = maxflow_grid_ref(np.asarray(p.cap_nbr), np.asarray(p.cap_src),
                               np.asarray(p.cap_sink))
        assert abs(float(rb.flow[b]) - ref) < 1e-4


def test_maxflow_ragged_padding_preserves_flow():
    """Zero-capacity padding leaves padded nodes inert: same flow, and the
    padded single solve bit-matches the batched ragged path."""
    rng = np.random.default_rng(2)
    shapes = [(5, 5), (8, 8), (4, 7)]
    probs = [GridProblem(*map(jnp.asarray, random_grid_problem(rng, h, w)))
             for h, w in shapes]
    out = solve_maxflow_batch(probs, bucket="max")
    for r, p, (h, w) in zip(out, probs, shapes):
        ref = maxflow_grid_ref(np.asarray(p.cap_nbr), np.asarray(p.cap_src),
                               np.asarray(p.cap_sink))
        assert abs(float(r.flow) - ref) < 1e-4
        padded_single = maxflow_grid(pad_grid_problem(p, 8, 8))
        assert float(r.flow) == float(padded_single.flow)
        np.testing.assert_array_equal(
            np.asarray(r.cut), np.asarray(padded_single.cut)[:h, :w])
        assert r.cut.shape == (h, w)


@pytest.mark.parametrize("bucket", ["max", "pow2", "exact"])
def test_maxflow_bucket_modes_agree(bucket):
    rng = np.random.default_rng(3)
    probs = [GridProblem(*map(jnp.asarray, random_grid_problem(rng, h, w)))
             for h, w in [(6, 6), (8, 5), (6, 6)]]
    out = solve_maxflow_batch(probs, bucket=bucket)
    for r, p in zip(out, probs):
        ref = maxflow_grid_ref(np.asarray(p.cap_nbr), np.asarray(p.cap_src),
                               np.asarray(p.cap_sink))
        assert abs(float(r.flow) - ref) < 1e-4


@pytest.mark.parametrize("method", ["pushrelabel", "auction"])
def test_assignment_batch_bitmatches_loop(method):
    # instance 0 gets a smaller max|c| -> shorter eps-scaling schedule, so
    # the per-instance liveness masks (not just the round masks) are on trial
    ws = np.stack([np.random.default_rng(i).integers(0, 101, (10, 10))
                   for i in range(5)])
    ws[0] //= 9
    rb = solve_assignment(jnp.asarray(ws), method=method)
    for b in range(ws.shape[0]):
        rs = solve_assignment(jnp.asarray(ws[b]), method=method)
        np.testing.assert_array_equal(np.asarray(rb.col_of_row[b]),
                                      np.asarray(rs.col_of_row))
        np.testing.assert_array_equal(np.asarray(rb.p_x[b]),
                                      np.asarray(rs.p_x))
        np.testing.assert_array_equal(np.asarray(rb.p_y[b]),
                                      np.asarray(rs.p_y))
        assert int(rb.weight[b]) == int(rs.weight) == optimal_weight(ws[b])
        assert int(rb.rounds[b]) == int(rs.rounds)
        assert int(rb.pushes[b]) == int(rs.pushes)
        assert int(rb.relabels[b]) == int(rs.relabels)
        assert bool(rb.converged[b]) and bool(rs.converged)


def test_assignment_batch_pallas_backend():
    ws = np.stack([np.random.default_rng(i).integers(0, 101, (12, 12))
                   for i in range(3)])
    rb = solve_assignment(jnp.asarray(ws), backend="pallas")
    for b in range(3):
        assert int(rb.weight[b]) == optimal_weight(ws[b])


def test_assignment_ragged_padding():
    """pad_cost_matrix's bonus shift forces real-real matchings: ragged
    batches recover each instance's exact optimum (incl. negative weights)."""
    ws = [np.random.default_rng(i).integers(-30, 71, (n, n))
          for i, n in enumerate([4, 9, 6, 9])]
    out = solve_assignment_batch(ws, bucket="max")
    for r, w in zip(out, ws):
        n = w.shape[0]
        assert sorted(np.asarray(r.col_of_row).tolist()) == list(range(n))
        assert int(r.weight) == optimal_weight(w)
    # and the batched padded solve bit-matches a loop of padded singles
    padded = [pad_cost_matrix(w, 9)[0] for w in ws]
    rb = solve_assignment(jnp.stack(padded))
    for b, wp in enumerate(padded):
        rs = solve_assignment(wp)
        np.testing.assert_array_equal(np.asarray(rb.col_of_row[b]),
                                      np.asarray(rs.col_of_row))
        assert int(rb.rounds[b]) == int(rs.rounds)


def test_assignment_ragged_unconverged_weight_is_guarded():
    """An instance that hits max_rounds may hold dummy-column matches: its
    col values stay >= n (detectable) and contribute 0 to weight instead of
    a clamped arbitrary real entry."""
    ws = [np.random.default_rng(i).integers(0, 101, (n, n))
          for i, n in enumerate([4, 12])]
    out = solve_assignment_batch(ws, bucket="max", max_rounds=1,
                                 rounds_per_heuristic=1)
    assert any(not bool(r.converged) for r in out)  # the scenario is live
    for r, w in zip(out, ws):
        n = w.shape[0]
        col = np.asarray(r.col_of_row)
        real = col < n
        # valid matches are a partial matching (no duplicated real column);
        # unmatched rows carry the >= n sentinel instead of aliasing col 0
        assert len(set(col[real].tolist())) == real.sum()
        expect = int(w[np.arange(n)[real], col[real]].sum())
        assert int(r.weight) == expect


def test_batch_empty_inputs():
    """An empty request queue is a no-op, not a crash."""
    assert solve_maxflow_batch([]) == []
    assert solve_assignment_batch([]) == []


def test_b1_buckets_match_direct_solves():
    """A B=1 bucket (one instance per distinct shape, bucket="exact") is
    just the direct solve: same flow/cut and same matching/weight."""
    rng = np.random.default_rng(20)
    p = GridProblem(*map(jnp.asarray, random_grid_problem(rng, 6, 4)))
    [r] = solve_maxflow_batch([p], bucket="exact")
    rs = maxflow_grid(p)
    assert float(r.flow) == float(rs.flow)
    np.testing.assert_array_equal(np.asarray(r.cut), np.asarray(rs.cut))
    assert int(r.rounds) == int(rs.rounds)

    w = rng.integers(-9, 40, (5, 5))
    [ra] = solve_assignment_batch([w], bucket="exact")
    assert int(ra.weight) == optimal_weight(w)
    assert sorted(np.asarray(ra.col_of_row).tolist()) == list(range(5))


def test_all_inert_bucket_converges_trivially():
    """A bucket padded ENTIRELY with inert instances (the degenerate shard
    padding case) converges with zero flow, zero rounds, and an all
    sink-free cut — no pushes, no relabels, no wedged loop."""
    batch = stack_grid_problems([inert_grid_problem(5, 7)] * 4)
    res = maxflow_grid_batch(batch)
    assert bool(jnp.all(res.converged))
    np.testing.assert_array_equal(np.asarray(res.rounds), np.zeros(4))
    np.testing.assert_array_equal(np.asarray(res.flow), np.zeros(4))
    assert not bool(jnp.any(res.cut))      # nothing reaches the sink

    # the assignment analogue: zero-weight matrices (any perfect matching
    # optimal) — the inert shard-padding instances of the ragged front end
    zero = solve_assignment(jnp.zeros((3, 4, 4), jnp.int32))
    assert bool(jnp.all(zero.converged))
    np.testing.assert_array_equal(np.asarray(zero.weight), np.zeros(3))


def test_pad_grid_problem_non_square_values():
    """Non-square pads: original block preserved exactly, padding
    zero-capacity (inert), and the padded solve keeps the original's flow
    and cut window."""
    rng = np.random.default_rng(21)
    p = GridProblem(*map(jnp.asarray, random_grid_problem(rng, 3, 7)))
    q = pad_grid_problem(p, 8, 9)
    assert q.cap_src.shape == (8, 9) and q.cap_nbr.shape == (4, 8, 9)
    np.testing.assert_array_equal(np.asarray(q.cap_nbr[:, :3, :7]),
                                  np.asarray(p.cap_nbr))
    np.testing.assert_array_equal(np.asarray(q.cap_src[:3, :7]),
                                  np.asarray(p.cap_src))
    assert float(jnp.sum(q.cap_src)) == float(jnp.sum(p.cap_src))  # inert pad
    assert float(jnp.sum(q.cap_nbr)) == float(jnp.sum(p.cap_nbr))
    rp, rs = maxflow_grid(q), maxflow_grid(p)
    assert float(rp.flow) == float(rs.flow)
    ref = maxflow_grid_ref(np.asarray(p.cap_nbr), np.asarray(p.cap_src),
                           np.asarray(p.cap_sink))
    assert abs(float(rp.flow) - ref) < 1e-4
    # padded nodes are sink-free: the cut window is the real instance's
    assert not bool(jnp.any(rp.cut[3:, :])) and not bool(jnp.any(rp.cut[:, 7:]))


def test_pad_cost_matrix_value_preservation_edges():
    """pad_cost_matrix edge cases: m == n is the identity modulo the bonus
    shift, and all-negative matrices keep their exact optimum through the
    dummy block."""
    w = np.asarray([[-5, -1], [-2, -7]])
    padded, bonus = pad_cost_matrix(w, 2)       # no growth: bonus shift only
    assert bonus == 8                           # 1 - (-7)
    np.testing.assert_array_equal(np.asarray(padded), w + bonus)
    [r] = solve_assignment_batch([w], bucket="max")
    assert int(r.weight) == optimal_weight(w) == -3

    big, _ = pad_cost_matrix(w, 5)
    assert big.shape == (5, 5)
    np.testing.assert_array_equal(np.asarray(big[2:, :]), 0)
    np.testing.assert_array_equal(np.asarray(big[:, 2:]), 0)


def test_routing_batched_matches_per_group():
    """The batch-polymorphic routers equal a loop over groups — the MoE
    'all groups in one dispatch' path is exactly the per-group path."""
    rng = np.random.default_rng(0)
    G, T, E, k = 3, 32, 8, 2
    cap = int(T * k / E * 1.25)
    s = jnp.asarray(rng.normal(size=(G, T, E)).astype(np.float32))
    for fn in (topk_route, auction_route):
        rb = fn(s, k, cap)
        for g in range(G):
            rg = fn(s[g], k, cap)
            np.testing.assert_array_equal(np.asarray(rb.dispatch[g]),
                                          np.asarray(rg.dispatch))
            np.testing.assert_array_equal(np.asarray(rb.combine[g]),
                                          np.asarray(rg.combine))
            np.testing.assert_array_equal(np.asarray(rb.prices[g]),
                                          np.asarray(rg.prices))
        assert rb.demand.shape == (G, E)
