"""Per-arch smoke tests: reduced configs, forward + train step + decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs, smoke_variant
from repro.models.layers import Sharder
from repro.models.model import (apply_model, init_caches, init_model,
                                layer_plan, plan_period)
from repro.optim.adamw import AdamWConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step

SHD = Sharder()
KEY = jax.random.PRNGKey(0)
ARCHS = list_configs()


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend_dim:
        return {"embeds": jnp.asarray(rng.normal(
                    size=(B, S, cfg.frontend_dim)).astype(np.float32)),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                      jnp.int32)}
    toks = rng.integers(0, cfg.vocab, (B, S + 1))
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = smoke_variant(get_config(arch))
    params, axes = init_model(cfg, KEY)
    batch = _batch(cfg)
    out = apply_model(params, axes, cfg, SHD, batch)
    B, S = batch["labels"].shape
    assert out.logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(out.logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    params, axes = init_model(cfg, KEY)
    tcfg = TrainConfig(optimizer=AdamWConfig(warmup_steps=2, decay_steps=10))
    state = init_train_state(cfg, tcfg, params)
    step = jax.jit(make_train_step(cfg, axes, tcfg, SHD))
    batch = _batch(cfg)
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    state, m2 = step(state, batch)      # second step: params moved, no NaNs
    assert np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) != float(m["loss"])


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).family != "encoder"])
def test_smoke_decode_consistency(arch):
    cfg = smoke_variant(get_config(arch))
    params, axes = init_model(cfg, KEY)
    rng = np.random.default_rng(0)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full = apply_model(params, axes, cfg, SHD, {"tokens": toks})
    caches, _ = init_caches(cfg, B, S_max=S + 4, dtype=jnp.float32)
    pre = apply_model(params, axes, cfg, SHD, {"tokens": toks[:, :S - 1]},
                      caches=caches)
    dec = apply_model(params, axes, cfg, SHD, {"tokens": toks[:, S - 1:]},
                      caches=pre.caches, decode=True, pos_offset=S - 1)
    a = np.asarray(full.logits[:, -1])
    b = np.asarray(dec.logits[:, 0])
    err = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    # MoE/hybrid: capacity truncation in the batched forward may drop a
    # token the (uncapped) decode path routes -> small expected skew.
    tol = 2e-2 if cfg.moe is not None else 3e-3
    assert err < tol, f"{arch}: {err}"


def test_layer_plans():
    ds = get_config("deepseek-v2-236b")
    plan = layer_plan(ds)
    assert plan[0] == ("attn", "mlp") and plan[1] == ("attn", "moe")
    assert plan_period(ds) == 1
    jb = get_config("jamba-v0.1-52b")
    plan = layer_plan(jb)
    assert plan_period(jb) == 8
    assert [m for m, _ in plan[:8]] == ["attn"] + ["mamba"] * 7
    assert [f for _, f in plan[:4]] == ["moe", "mlp", "moe", "mlp"]
    mb = get_config("mamba2-370m")
    assert all(m == "mamba" and f is None for m, f in layer_plan(mb))


def test_param_counts_in_range():
    """Config param counts should be near the advertised model sizes."""
    expect = {
        "nemotron-4-340b": (300e9, 380e9),
        "minitron-8b": (7e9, 10e9),
        "smollm-135m": (120e6, 150e6),
        "command-r-plus-104b": (95e9, 115e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "mamba2-370m": (300e6, 440e6),
        "jamba-v0.1-52b": (45e9, 60e9),
        "chameleon-34b": (30e9, 38e9),
        "hubert-xlarge": (0.8e9, 1.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}," \
                              f"{hi/1e9}]B"


def test_moe_active_params():
    ds = get_config("deepseek-v2-236b")
    assert ds.active_param_count() < 0.2 * ds.param_count()
    phi = get_config("phi3.5-moe-42b-a6.6b")
    frac = phi.active_param_count() / phi.param_count()
    assert 0.1 < frac < 0.25            # ~6.6/42


def test_kv_quant_decode_consistency():
    """int8 KV cache: decode matches full forward within quant tolerance."""
    cfg = dataclasses.replace(smoke_variant(get_config("smollm-135m")),
                              kv_quant=True)
    params, axes = init_model(cfg, KEY)
    rng = np.random.default_rng(0)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full = apply_model(params, axes, cfg, SHD, {"tokens": toks})
    caches, _ = init_caches(cfg, B, S_max=S + 4, dtype=jnp.float32)
    pre = apply_model(params, axes, cfg, SHD, {"tokens": toks[:, :S - 1]},
                      caches=caches)
    dec = apply_model(params, axes, cfg, SHD, {"tokens": toks[:, S - 1:]},
                      caches=pre.caches, decode=True, pos_offset=S - 1)
    a = np.asarray(full.logits[:, -1])
    b = np.asarray(dec.logits[:, 0])
    err = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert err < 5e-2, err              # int8 KV quantization tolerance
