"""The matching kind end-to-end: oracle equality + the full invariant stack.

This file is the registry's acceptance test (ISSUE: "prove the seam with a
third kind"): bipartite maximum-cardinality matching
(``repro.core.matching``, lock-free BFS augmenting-path phases after
Deveci et al., arXiv:1303.1379) must ride EVERY layer the original two
kinds ride — ragged pad-and-bucket, pow2 bucketing, mesh sharding,
early-exit compaction, the sync engine, and the async scheduler — with no
changes to those layers, and hold the same bit-match contract at each:

* CORRECTNESS — cardinality equals the NumPy Hopcroft–Karp oracle on
  random and adversarial instances (hidden perfect matching, star,
  block-diagonal/disconnected), and every reported matching is a valid
  matching of the input graph;
* batched == a loop of single solves (every leaf, including rounds);
* kernel == reference — the pallas frontier-expansion kernel bit-matches
  the pure-jnp oracle tile-by-tile, and ``backend="pallas"`` bit-matches
  ``backend="xla"`` end-to-end;
* sharded == unsharded (2 and the full emulated device count, with inert
  shard padding for non-divisible queues);
* compacted == masked; async futures == sync flush.

Multi-device is emulated exactly as in test_shard.py: a slow subprocess
test relaunches this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; CI also runs the
file directly with the flag exported.
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batch import solve_batch
from repro.core.kinds import get_kind
from repro.core.matching import (MatchingResult, hopcroft_karp,
                                 match_bipartite, match_bipartite_batch,
                                 prepare_matching_buckets,
                                 validate_matching_problem)
from repro.core.matching.ref import (disconnected_instance,
                                     perfect_matching_instance,
                                     random_bipartite, star_instance)
from repro.kernels.frontier.kernel import INF, frontier
from repro.kernels.frontier.ref import frontier_ref
from repro.launch.mesh import make_solver_mesh
from repro.serve.engine import SolverEngine

N_DEV = len(jax.devices())
FORCE_FLAG = "--xla_force_host_platform_device_count=8"
multi = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices; covered via the subprocess test")
SHARD_COUNTS = sorted({2, N_DEV}) if N_DEV >= 2 else []


def _assert_results_equal(a: MatchingResult, b: MatchingResult):
    for name, la, lb in zip(a._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(la), np.asarray(lb), err_msg=name)


def _assert_valid_matching(adj: np.ndarray, res: MatchingResult):
    """The reported matching is a real matching OF THIS GRAPH."""
    mr = np.asarray(res.match_row)
    mc = np.asarray(res.match_col)
    for i, j in enumerate(mr):
        if j >= 0:
            assert adj[i, j], f"matched non-edge ({i}, {j})"
            assert mc[j] == i, f"inconsistent match_col at col {j}"
    for j, i in enumerate(mc):
        if i >= 0:
            assert mr[i] == j, f"inconsistent match_row at row {i}"
    assert int(res.cardinality) == int(np.sum(mr >= 0))


@pytest.mark.slow  # full matching suite in a fresh 8-device process
@pytest.mark.skipif(N_DEV >= 2, reason="already multi-device")
def test_forced_multi_device_subprocess():
    """Relaunch this file under 8 emulated host devices and require green."""
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE_FLAG).strip()
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", str(__file__)],
        cwd=root, env=env, capture_output=True, text=True, timeout=1500)
    assert r.returncode == 0, f"\n--- stdout ---\n{r.stdout}\n{r.stderr}"
    assert "passed" in r.stdout


# ------------------------------------------------------- oracle equality

def test_cardinality_matches_hopcroft_karp_random():
    rng = np.random.default_rng(0)
    for t in range(25):
        nl, nr = int(rng.integers(1, 24)), int(rng.integers(1, 24))
        adj = random_bipartite(rng, nl, nr, p=float(rng.uniform(0.05, 0.6)))
        _, _, card = hopcroft_karp(adj)
        res = match_bipartite(adj)
        assert int(res.cardinality) == card, (t, nl, nr)
        assert bool(res.converged), "Berge certificate missing"
        _assert_valid_matching(adj, res)


def test_cardinality_matches_oracle_adversarial():
    rng = np.random.default_rng(1)
    # hidden perfect matching: the answer must be exactly n, and greedy
    # init must not strand rows that only long alternating paths recover
    for n in (4, 9, 17):
        adj = perfect_matching_instance(rng, n)
        for greedy_init in (True, False):
            res = match_bipartite(adj, greedy_init=greedy_init)
            assert int(res.cardinality) == n
            _assert_valid_matching(adj, res)
    # star: every tree fights for one column; exactly one may win
    for nl, nr, hub in ((7, 5, 0), (12, 6, 4), (1, 1, 0)):
        res = match_bipartite(star_instance(nl, nr, hub=hub))
        assert int(res.cardinality) == 1
    # disconnected blocks incl. isolated vertices (zero blocks)
    for _ in range(5):
        adj = disconnected_instance(
            rng, [(3, 2), (0, 4), (5, 5), (2, 0), (1, 1)])
        _, _, card = hopcroft_karp(adj)
        res = match_bipartite(adj)
        assert int(res.cardinality) == card
        _assert_valid_matching(adj, res)
    # fully empty graph: converges in 0 rounds
    res = match_bipartite(np.zeros((4, 6), bool))
    assert int(res.cardinality) == 0 and int(res.rounds) == 0
    assert bool(res.converged)


# ----------------------------------------------------- batched == single

def test_batched_equals_loop_of_single_solves():
    rng = np.random.default_rng(2)
    adjs = [random_bipartite(rng, 9, 11, p=0.25) for _ in range(6)]
    batched = match_bipartite_batch(jnp.asarray(np.stack(adjs)))
    for b, adj in enumerate(adjs):
        solo = match_bipartite(adj)
        _assert_results_equal(
            MatchingResult(*(np.asarray(l)[b] for l in batched)), solo)


def test_single_instance_rejects_batched_input_and_vice_versa():
    with pytest.raises(ValueError, match="ONE instance"):
        match_bipartite(np.zeros((2, 3, 3), bool))
    with pytest.raises(ValueError, match="single instance"):
        match_bipartite_batch(np.zeros((3, 3), bool))


# ------------------------------------------------------ kernel == oracle

def test_frontier_kernel_matches_reference():
    rng = np.random.default_rng(3)
    for nl, nr, br, bc in ((8, 8, 8, 8), (16, 32, 4, 8), (12, 24, 3, 24)):
        adj = jnp.asarray(random_bipartite(rng, nl, nr, p=0.3))
        root = jnp.where(jnp.asarray(rng.random(nl) < 0.5),
                         jnp.arange(nl, dtype=jnp.int32), INF)
        match = jnp.asarray(
            rng.integers(-1, nr, nl).astype(np.int32))
        got = frontier(adj, root, match, block_rows=br, block_cols=bc,
                       interpret=True)
        want = frontier_ref(adj, root, match)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_pallas_backend_bitmatches_xla_end_to_end():
    rng = np.random.default_rng(4)
    for shape in ((8, 8), (16, 8)):
        adj = random_bipartite(rng, *shape, p=0.3)
        rx = match_bipartite(adj, backend="xla")
        rp = match_bipartite(adj, backend="pallas")
        _assert_results_equal(rx, rp)
    # batched too (the pallas op is vmapped over the batch axis)
    adjs = np.stack([random_bipartite(rng, 8, 8) for _ in range(4)])
    _assert_results_equal(
        match_bipartite_batch(jnp.asarray(adjs), backend="xla"),
        match_bipartite_batch(jnp.asarray(adjs), backend="pallas"))


# ----------------------------------------------- ragged front end (batch)

def test_ragged_front_end_matches_single_solves():
    rng = np.random.default_rng(5)
    adjs = [random_bipartite(rng, int(rng.integers(1, 14)),
                             int(rng.integers(1, 14)))
            for _ in range(9)]
    for bucket in ("max", "pow2", "exact"):
        outs = solve_batch("matching", adjs, bucket=bucket)
        for adj, r in zip(adjs, outs):
            assert r.match_row.shape == (adj.shape[0],)
            assert r.match_col.shape == (adj.shape[1],)
            _assert_valid_matching(adj, r)
            _, _, card = hopcroft_karp(adj)
            assert int(r.cardinality) == card


def test_prepare_buckets_pads_and_stacks():
    rng = np.random.default_rng(6)
    adjs = [random_bipartite(rng, 3, 5), random_bipartite(rng, 7, 2)]
    [prep] = prepare_matching_buckets(adjs, bucket="max")
    assert prep.kind == "matching" and prep.shape == (7, 5)
    assert prep.stacked.shape == (2, 7, 5)
    assert prep.stacked.dtype == jnp.bool_
    # padding is edge-less: the pad region holds no True entry
    assert not np.asarray(prep.stacked)[0, 3:, :].any()
    assert not np.asarray(prep.stacked)[1, :, 2:].any()


def test_compacted_equals_masked():
    rng = np.random.default_rng(7)
    adjs = np.stack([random_bipartite(rng, 10, 10, p=p)
                     for p in (0.05, 0.5, 0.1, 0.9, 0.2)])
    _assert_results_equal(
        match_bipartite_batch(jnp.asarray(adjs), compact=False),
        match_bipartite_batch(jnp.asarray(adjs), compact=True))


# ------------------------------------------------------------- sharding

@multi
def test_sharded_equals_unsharded():
    rng = np.random.default_rng(8)
    adjs = jnp.asarray(np.stack(
        [random_bipartite(rng, 8, 12) for _ in range(8)]))
    base = match_bipartite_batch(adjs)
    for s in SHARD_COUNTS:
        got = match_bipartite_batch(adjs, mesh=make_solver_mesh(s))
        _assert_results_equal(base, got)


@multi
def test_sharded_ragged_queue_inert_padding():
    """A queue size not divisible by the shard count rides the front end's
    inert padding; results still match the unsharded ragged solve."""
    rng = np.random.default_rng(9)
    adjs = [random_bipartite(rng, int(rng.integers(2, 10)),
                             int(rng.integers(2, 10)))
            for _ in range(5)]                      # 5 % 2 != 0
    base = solve_batch("matching", adjs)
    for s in SHARD_COUNTS:
        got = solve_batch("matching", adjs, mesh=make_solver_mesh(s))
        for b, g in zip(base, got):
            _assert_results_equal(b, g)


@multi
def test_sharded_compacted_equals_masked():
    rng = np.random.default_rng(10)
    adjs = jnp.asarray(np.stack(
        [random_bipartite(rng, 8, 8) for _ in range(8)]))
    mesh = make_solver_mesh(2)
    _assert_results_equal(
        match_bipartite_batch(adjs, mesh=mesh),
        match_bipartite_batch(adjs, mesh=mesh, compact=True))


# ----------------------------------------------------------- serve layer

def test_sync_engine_serves_matching_with_zero_engine_changes():
    rng = np.random.default_rng(11)
    mesh = make_solver_mesh() if N_DEV >= 2 else None
    engine = SolverEngine(mesh=mesh,
                          solver_kw={"matching": {"backend": "xla"}})
    adjs = [random_bipartite(rng, n, n) for n in (4, 6, 4)]
    tickets = [engine.submit("matching", a) for a in adjs]
    # edge-list payloads canonicalize through the registered validator
    t_edge = engine.submit(
        "matching", (np.array([[0, 1], [1, 0]]), (2, 2)))
    out = engine.flush()
    assert sorted(out) == tickets + [t_edge]
    base = solve_batch("matching", adjs, mesh=mesh)
    for t, b in zip(tickets, base):
        _assert_results_equal(out[t], b)
    assert int(out[t_edge].cardinality) == 2


@pytest.mark.serve
def test_async_scheduler_serves_matching():
    """Futures bit-match the sync flush of the same chunks — the matching
    kind rides the scheduler with zero scheduler changes."""
    from repro.serve.scheduler import AsyncSolverEngine
    rng = np.random.default_rng(12)
    adjs = [random_bipartite(rng, 8, 8) for _ in range(8)]
    with AsyncSolverEngine(max_batch=4, max_delay_ms=600_000.0) as eng:
        futs = [eng.submit("matching", a) for a in adjs]
        res = [f.result(timeout=120.0) for f in futs]
        assert eng.metrics.convergence.spread("matching") is not None
        snap = eng.metrics.snapshot()
    assert "matching" in snap["spread_ewma"]

    sync = SolverEngine()
    base = []
    for lo in range(0, len(adjs), 4):
        ts = [sync.submit("matching", a) for a in adjs[lo:lo + 4]]
        out = sync.flush()
        base += [out[t] for t in ts]
    for got, want in zip(res, base):
        _assert_results_equal(got, want)


# ----------------------------------------------------- validator rejects

def test_validator_rejects_malformed_payloads():
    # non-0/1 entries are not a bipartite adjacency
    with pytest.raises(ValueError, match="0/1"):
        validate_matching_problem(np.array([[0, 2], [1, 0]]))
    with pytest.raises(ValueError, match="malformed matching"):
        validate_matching_problem(np.zeros((3,)))           # 1-D
    with pytest.raises(ValueError, match="empty side"):
        validate_matching_problem(np.zeros((0, 3), bool))
    with pytest.raises(ValueError, match="negative vertex id"):
        validate_matching_problem((np.array([[0, -1]]), (2, 2)))
    with pytest.raises(ValueError, match="out of range"):
        validate_matching_problem((np.array([[0, 5]]), (2, 2)))
    with pytest.raises(ValueError, match="integer vertex ids"):
        validate_matching_problem((np.array([[0.5, 1.0]]), (2, 2)))
    # rejected before any ticket exists
    engine = SolverEngine()
    with pytest.raises(ValueError, match="malformed matching"):
        engine.submit("matching", np.array([[0, 2], [1, 0]]))
    assert engine.pending() == 0


def test_validator_canonicalizes_good_payloads():
    a = validate_matching_problem([[1, 0], [0, 1]])
    assert a.dtype == bool and a.shape == (2, 2)
    e = validate_matching_problem(
        (np.array([[0, 0], [1, 2], [1, 0]]), (2, 3)))
    assert e.shape == (2, 3) and e.sum() == 3 and e[1, 2]


# ----------------------------------------------------------- registration

def test_matching_kind_registration_surface():
    kind = get_kind("matching")
    assert kind.name == "matching"
    inert = kind.inert_problem((4, 6))
    assert inert.shape == (4, 6) and not inert.any()
    # the cached LoopSpec factory returns the SAME spec for equal knobs
    assert kind.loop_spec() is kind.loop_spec()
    assert kind.loop_spec(max_rounds=7) is not kind.loop_spec()
