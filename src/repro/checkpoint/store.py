"""Sharded checkpointing: npz-per-host + JSON manifest, atomic commit.

Layout:  <dir>/step_<N>/shard_<proc>.npz + manifest.json (written LAST —
its presence marks the checkpoint complete; partial writes are never
visible to readers). Restore reshards automatically: each leaf is assembled
from the saved global array and ``jax.device_put`` to the *current* mesh's
sharding, so restarting with a different topology (elastic scaling after a
node failure) is a first-class path, not a special case.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Blocking save of a (possibly sharded) pytree. Returns the path."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    proc = jax.process_index()
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step_{step}_")
    try:
        arrs = {}
        for i, leaf in enumerate(leaves):
            # each process saves its addressable data; single-process saves all
            arrs[f"leaf_{i}"] = np.asarray(jax.device_get(leaf))
        np.savez(os.path.join(tmp, f"shard_{proc}.npz"), **arrs)
        if proc == 0:
            meta = {
                "step": step,
                "n_leaves": len(leaves),
                "dtypes": [str(l.dtype) for l in leaves],
                "shapes": [list(l.shape) for l in leaves],
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
        os.replace(tmp, final)            # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "manifest.json")):
            best = max(best or -1, int(d.split("_")[1]))
    return best


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; optionally reshard.

    ``shardings`` may target a different mesh than the checkpoint was saved
    from (elastic restart): arrays are re-placed with jax.device_put.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)
    leaves, treedef = _flatten(like_tree)
    assert len(leaves) == meta["n_leaves"], "checkpoint/model mismatch"
    data = np.load(os.path.join(path, f"shard_{jax.process_index()}.npz"))
    out = []
    sh_leaves = (_flatten(shardings)[0] if shardings is not None
                 else [None] * len(leaves))
    for i, (like, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = jnp.asarray(data[f"leaf_{i}"], dtype=like.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree.unflatten(treedef, out)
