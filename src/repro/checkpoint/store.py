"""Sharded checkpointing: npz-per-host + JSON manifest, atomic commit.

Layout:  <dir>/step_<N>/shard_<proc>.npz + manifest.json (written LAST —
its presence marks the checkpoint complete; partial writes are never
visible to readers). Restore reshards automatically: each leaf is assembled
from the saved global array and ``jax.device_put`` to the *current* mesh's
sharding, so restarting with a different topology (elastic scaling after a
node failure) is a first-class path, not a special case.

Restore VALIDATES each leaf against the manifest's saved ``dtypes`` /
``shapes`` and against ``like_tree`` before loading anything onto devices:
a dtype or shape mismatch raises ``ValueError`` naming the leaf, instead
of silently casting (which used to truncate e.g. float32 checkpoints into
int32 model trees without a sound).

Beyond step checkpoints, the store doubles as a flat keyed blob store for
the warm-start solution cache (``repro.core.warm.SolutionCache`` spills
evicted entries here): ``put(dir, key, tree)`` / ``get(dir, key,
like_tree=None)`` write ``kv_<key>/`` entries with the same atomic-commit
and manifest discipline.  ``_gc`` only ever touches ``step_<digits>``
directories, so kv entries (and any foreign directory a user drops into
the checkpoint root) survive checkpoint rotation.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

# the only directories save/restore/_gc own; anything else in ckpt_dir
# (kv_* entries, foreign dirs, loose files) is never GC'd or parsed
_STEP_RE = re.compile(r"^step_(\d{8,})$")


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Blocking save of a (possibly sharded) pytree. Returns the path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    leaves, _ = _flatten(tree)
    _write_entry(ckpt_dir, final, leaves, extra_meta={"step": step})
    _gc(ckpt_dir, keep)
    return final


def _write_entry(ckpt_dir: str, final: str, leaves, *, extra_meta=None):
    """Write leaves + manifest into ``final`` with an atomic commit."""
    proc = jax.process_index()
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_ckpt_")
    try:
        arrs = {}
        for i, leaf in enumerate(leaves):
            # each process saves its addressable data; single-process saves all
            arrs[f"leaf_{i}"] = np.asarray(jax.device_get(leaf))
        np.savez(os.path.join(tmp, f"shard_{proc}.npz"), **arrs)
        if proc == 0:
            meta = {
                "n_leaves": len(leaves),
                "dtypes": [str(arrs[f"leaf_{i}"].dtype)
                           for i in range(len(leaves))],
                "shapes": [list(arrs[f"leaf_{i}"].shape)
                           for i in range(len(leaves))],
            }
            meta.update(extra_meta or {})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
        try:
            os.replace(tmp, final)        # atomic commit
        except OSError:
            # target exists as a non-empty dir (kv overwrite): swap the
            # old entry aside first so the commit itself stays a single
            # atomic rename, then drop the displaced entry
            old = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_old_")
            os.replace(final, os.path.join(old, "prev"))
            os.replace(tmp, final)
            shutil.rmtree(old, ignore_errors=True)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def _gc(ckpt_dir: str, keep: int):
    # defensively skip anything that is not a committed step directory:
    # kv_* blob entries, users' foreign dirs, and in-flight .tmp_* writes
    # must never be collected by checkpoint rotation.
    steps = sorted(d for d in os.listdir(ckpt_dir) if _STEP_RE.match(d))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in os.listdir(ckpt_dir):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            best = max(best or -1, int(m.group(1)))
    return best


def _load_validated(path: str, like_leaves, meta):
    """Load shard leaves, validating dtype/shape against manifest + likes.

    ``like_leaves`` may be ``None`` to accept whatever the manifest says
    (the keyed blob path, where the caller holds the structure).
    """
    n = meta["n_leaves"]
    if like_leaves is not None and len(like_leaves) != n:
        raise ValueError(
            f"checkpoint/model mismatch at {path}: checkpoint has {n} "
            f"leaves, like_tree has {len(like_leaves)}")
    data = np.load(os.path.join(path, f"shard_{jax.process_index()}.npz"))
    out = []
    for i in range(n):
        arr = data[f"leaf_{i}"]
        want_dtype, want_shape = meta["dtypes"][i], tuple(meta["shapes"][i])
        if str(arr.dtype) != want_dtype or arr.shape != want_shape:
            raise ValueError(
                f"corrupt checkpoint {path}: leaf {i} is "
                f"{arr.dtype}{list(arr.shape)} but the manifest recorded "
                f"{want_dtype}{list(want_shape)}")
        if like_leaves is not None:
            like = like_leaves[i]
            like_dtype = str(np.dtype(like.dtype))
            like_shape = tuple(np.shape(like))
            if want_dtype != like_dtype or want_shape != like_shape:
                raise ValueError(
                    f"checkpoint/model mismatch at {path}: leaf {i} was "
                    f"saved as {want_dtype}{list(want_shape)} but like_tree "
                    f"expects {like_dtype}{list(like_shape)} — refusing to "
                    f"cast silently")
        out.append(arr)
    return out


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; optionally reshard.

    ``shardings`` may target a different mesh than the checkpoint was saved
    from (elastic restart): arrays are re-placed with jax.device_put.
    Every leaf's saved dtype and shape must match ``like_tree`` exactly;
    mismatches raise ``ValueError`` instead of casting.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)
    leaves, treedef = _flatten(like_tree)
    arrs = _load_validated(path, leaves, meta)
    sh_leaves = (_flatten(shardings)[0] if shardings is not None
                 else [None] * len(leaves))
    out = []
    for arr, like, sh in zip(arrs, leaves, sh_leaves):
        a = jnp.asarray(arr, dtype=like.dtype)
        out.append(jax.device_put(a, sh) if sh is not None else a)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# keyed blob store (kv_* entries) — the SolutionCache spill target


def _kv_path(ckpt_dir: str, key: str) -> str:
    # keys are content hashes ([0-9a-f]); reject anything that could
    # escape the directory or collide with the step_* namespace
    if not re.fullmatch(r"[A-Za-z0-9._-]+", key):
        raise ValueError(f"invalid blob key {key!r}: use [A-Za-z0-9._-]+")
    return os.path.join(ckpt_dir, f"kv_{key}")


def put(ckpt_dir: str, key: str, tree) -> str:
    """Atomically store a pytree under ``key`` (overwrites). Returns path."""
    leaves, _ = _flatten(tree)
    return _write_entry(ckpt_dir, _kv_path(ckpt_dir, key), leaves,
                        extra_meta={"key": key})


def get(ckpt_dir: str, key: str, like_tree=None):
    """Load the pytree stored under ``key``; ``None`` if absent.

    With ``like_tree`` the result takes its structure (validated leaf by
    leaf like :func:`restore`); without it, the flat list of numpy leaves
    is returned and the caller re-attaches its own structure.
    """
    path = _kv_path(ckpt_dir, key)
    manifest = os.path.join(path, "manifest.json")
    if not os.path.exists(manifest):
        return None
    with open(manifest) as f:
        meta = json.load(f)
    if like_tree is None:
        return _load_validated(path, None, meta)
    leaves, treedef = _flatten(like_tree)
    arrs = _load_validated(path, leaves, meta)
    return jax.tree.unflatten(
        treedef, [jnp.asarray(a, dtype=l.dtype) for a, l in zip(arrs, leaves)])
