"""Mamba2 (SSD — state-space duality) block: chunked train, recurrent decode.

Training uses the SSD chunked algorithm (Dao & Gu 2024): within a chunk the
recurrence is computed as a masked quadratic form (MXU-friendly), across
chunks a short scan passes the (H, P, N) state. Decode keeps the state
explicitly. Group count = 1 (B/C shared across heads), as in mamba2-370m.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ParamFactory, Sharder, rmsnorm


class SSMCache(NamedTuple):
    state: jax.Array       # (B, H, P, N)
    conv: jax.Array        # (B, d_conv-1, d_inner + 2*N) rolling conv input
    length: jax.Array


def init_mamba(pf: ParamFactory, path: str, cfg):
    s, D = cfg.ssm, cfg.d_model
    di, N, H = s.d_inner(D), s.d_state, s.n_heads(D)
    conv_dim = di + 2 * N
    return {
        "in_proj": pf.dense(f"{path}.in_proj",
                            (D, 2 * di + 2 * N + H), ("fsdp", "tp")),
        "conv_w": pf.dense(f"{path}.conv_w", (s.d_conv, conv_dim),
                           (None, "tp"), scale=s.d_conv ** -0.5),
        "conv_b": pf.zeros(f"{path}.conv_b", (conv_dim,), ("tp",)),
        "A_log": pf.ones(f"{path}.A_log", (H,), (None,)),
        "dt_bias": pf.zeros(f"{path}.dt_bias", (H,), (None,)),
        "D": pf.ones(f"{path}.D", (H,), (None,)),
        "norm_g": pf.ones(f"{path}.norm_g", (di,), ("tp",)),
        "out_proj": pf.dense(f"{path}.out_proj", (di, D), ("tp", "fsdp"),
                             scale=di ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }


def _causal_conv(u, w, b, cache_conv=None):
    """Depthwise causal conv1d. u: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    if cache_conv is not None:                    # decode: S == 1
        window = jnp.concatenate([cache_conv, u], axis=1)    # (B, K, C)
        out = jnp.einsum("bkc,kc->bc", window, w)[:, None] + b
        return jax.nn.silu(out), window[:, 1:]
    pad = jnp.zeros_like(u[:, :K - 1])
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(K)) + b
    return jax.nn.silu(out), up[:, -(K - 1):] if K > 1 else None


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk, init_state=None):
    """SSD scan. xh: (B,S,H,P); dt: (B,S,H); Bm/Cm: (B,S,N).

    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    # per-step decay: a_t = exp(-dt_t * A); work with the positive exponent
    dA = dt * A[None, None, :]                    # (B,S,H) >= 0
    dA_c = dA.reshape(Bsz, nc, chunk, H)
    x_c = xh.reshape(Bsz, nc, chunk, H, P)
    dt_c = dt.reshape(Bsz, nc, chunk, H)
    B_c = Bm.reshape(Bsz, nc, chunk, N)
    C_c = Cm.reshape(Bsz, nc, chunk, N)

    cum = jnp.cumsum(dA_c, axis=2)                # (B,nc,Q,H) inclusive
    total = cum[:, :, -1]                         # (B,nc,H)
    # intra-chunk quadratic term: x_j's weight in h_i is prod_{l=j+1..i} a_l
    # = exp(-(cum_i - cum_j)) for i >= j (own-step input is not decayed).
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    iq = jnp.arange(chunk)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    # clamp BEFORE exp: masked entries have li < 0 and exp(-li) = inf,
    # whose cotangent is inf*0 = NaN (the where-grad trap)
    li = jnp.where(causal, li, 0.0)
    L = jnp.where(causal, jnp.exp(-li), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)[..., None] * L \
        * dt_c[:, :, None, :, :]                  # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, x_c)

    # chunk-final states: sum_j exp(-(total - cum_j)) * dt_j * B_j x_j
    decay_to_end = jnp.exp(cum - total[:, :, None])        # (B,nc,Q,H)
    st = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                    decay_to_end * dt_c, B_c, x_c)         # per-chunk state

    # scan across chunks: h_c = h_{c-1} * exp(-total_c) + st_c
    def body(h, inp):
        tot, s_c = inp
        h_new = h * jnp.exp(-tot)[:, :, None, None] + s_c
        return h_new, h            # emit PRE-chunk state
    h0 = init_state if init_state is not None else \
        jnp.zeros((Bsz, H, P, N), jnp.float32)
    hT, h_prev = jax.lax.scan(
        body, h0, (total.swapaxes(0, 1), st.swapaxes(0, 1).astype(jnp.float32)))
    h_prev = h_prev.swapaxes(0, 1)                # (B,nc,H,P,N) pre-chunk

    # inter-chunk contribution: y_i += C_i . (exp(-cum_i) * h_prev)
    decay_from_start = jnp.exp(-cum)              # h_{-1} decayed through i
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         C_c, decay_from_start, h_prev.astype(C_c.dtype))
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, hT


def mamba_apply(p, x, cfg, shd: Sharder, *,
                cache: Optional[SSMCache] = None, decode: bool = False):
    s, D = cfg.ssm, cfg.d_model
    di, N, H, P = s.d_inner(D), s.d_state, s.n_heads(cfg.d_model), s.head_dim
    B, S, _ = x.shape

    zxbcdt = x @ p["in_proj"][0]
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    xbc = shd.constrain(xbc, "batch", None, "tp")

    conv_cache = cache.conv if decode else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"][0], p["conv_b"][0],
                                 conv_cache)
    xh, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    xh = xh.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][0])
    A = jnp.exp(p["A_log"][0].astype(jnp.float32))          # (H,) positive

    if decode:
        assert cache is not None and S == 1
        dA = jnp.exp(-dt[:, 0] * A[None, :])                # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], Bm[:, 0].astype(
            jnp.float32), xh[:, 0].astype(jnp.float32))
        h_new = cache.state * dA[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32),
                       h_new)[:, None]                      # (B,1,H,P)
        new_cache = SSMCache(h_new, new_conv, cache.length + 1)
    else:
        y, hT = _ssd_chunked(xh.astype(jnp.float32), dt, A,
                             Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                             min(s.chunk, S))
        new_cache = SSMCache(hT, new_conv, jnp.int32(S)) \
            if cache is not None else None

    y = y + xh.astype(jnp.float32) * p["D"][0][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_g"][0])
    out = y @ p["out_proj"][0]
    return shd.constrain(out, "batch", None, None), new_cache
