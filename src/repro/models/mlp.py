"""MLPs: dense (SwiGLU / squared-ReLU / GELU) and MoE with flow routing.

The MoE layer is where the paper's technique is a first-class feature:
``cfg.moe.router == "flow"`` routes tokens with the capacity-constrained
ε-auction from ``repro.core.routing`` (the assignment problem of §5 solved
inside every MoE layer), ``"topk"`` is the standard baseline.

Dispatch is sort-based (argsort by expert id + capacity-slot scatter), which
keeps every shape static for pjit and maps to an all-to-all when experts are
sharded over the model axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.routing import auction_route, topk_route
from repro.models.layers import ACTIVATIONS, ParamFactory, Sharder


def init_mlp(pf: ParamFactory, path: str, cfg, d_ff: int | None = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    p = {
        "w1": pf.dense(f"{path}.w1", (D, F), ("fsdp", "tp")),
        "w2": pf.dense(f"{path}.w2", (F, D), ("tp", "fsdp"),
                       scale=F ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.gated_mlp:
        p["w3"] = pf.dense(f"{path}.w3", (D, F), ("fsdp", "tp"))
    return p


def mlp_apply(p, x, cfg, shd: Sharder):
    act = ACTIVATIONS[cfg.mlp_act]
    h = act(x @ p["w1"][0])
    if cfg.gated_mlp:
        h = h * (x @ p["w3"][0])
    h = shd.constrain(h, "batch", None, "tp")
    return shd.constrain(h @ p["w2"][0], "batch", None, None)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def init_moe(pf: ParamFactory, path: str, cfg):
    e, D = cfg.moe, cfg.d_model
    F = e.d_ff_expert
    p = {
        "gate": pf.dense(f"{path}.gate", (D, e.n_experts), ("fsdp", None),
                         scale=D ** -0.5),
        "w1": pf.dense(f"{path}.w1", (e.n_experts, D, F),
                       ("tp", "fsdp", None)),
        "w2": pf.dense(f"{path}.w2", (e.n_experts, F, D),
                       ("tp", None, "fsdp"),
                       scale=F ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.gated_mlp:
        p["w3"] = pf.dense(f"{path}.w3", (e.n_experts, D, F),
                           ("tp", "fsdp", None))
    if e.n_shared:
        p["shared"] = init_mlp(pf, f"{path}.shared", cfg,
                               d_ff=F * e.n_shared)
    return p


def _expert_ffn(buf, p, cfg):
    """buf: (E, C, D) -> (E, C, D); per-expert matmuls on the MXU."""
    act = ACTIVATIONS[cfg.mlp_act]
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["w1"][0]))
    if cfg.gated_mlp:
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["w3"][0])
    return jnp.einsum("ecf,efd->ecd", h, p["w2"][0])


def _dispatch_group(xt, disp, combine_logits, p, cfg, *, k, capacity):
    """Dispatch + expert FFN + combine for ONE token group (vmapped).

    Routing decisions arrive precomputed (``disp``): the router itself is
    batch-polymorphic and runs ONCE over all groups before the vmap, so all
    groups' assignment problems are solved in a single dispatch. Everything
    here (argsort, capacity slots, scatter/gather) is local to the group =
    local to one data shard after vmap, so none of it generates cross-device
    traffic (DESIGN.md §5; the global-sort variant cost 55 TB/device of
    all-reduce on deepseek train_4k).
    """
    T, D = xt.shape
    E = cfg.moe.n_experts
    gates = jax.nn.softmax(jnp.where(disp, combine_logits, -1e9), axis=-1)
    combine = jnp.where(disp, gates, 0.0).astype(xt.dtype)

    choice_e = jnp.where(disp, jnp.arange(E)[None, :], E)
    topv = jax.lax.top_k(-choice_e, k)[0]              # k smallest expert ids
    flat_e = (-topv).reshape(-1)                       # (T*k,) expert or E
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, st = flat_e[order], flat_t[order]
    starts = jnp.searchsorted(se, jnp.arange(E + 1))
    pos = jnp.arange(T * k) - starts[se.clip(0, E)]
    ok = (se < E) & (pos < capacity)
    se_c = jnp.where(ok, se, E)                        # OOB -> dropped
    pos_c = jnp.where(ok, pos, 0)

    buf = jnp.zeros((E, capacity, D), xt.dtype)
    buf = buf.at[se_c, pos_c].set(xt[st], mode="drop")

    out_buf = _expert_ffn(buf, p, cfg)

    gathered = out_buf[se_c, pos_c]                    # (T*k, D)
    wts = jnp.take_along_axis(combine[st], se_c[:, None], 1)[:, 0]
    contrib = jnp.where(ok[:, None], gathered * wts[:, None], 0.0)
    return jnp.zeros((T, D), xt.dtype).at[st].add(contrib)


def moe_apply(p, x, cfg, shd: Sharder, decode: bool = False):
    """x: (B, S, D) -> (B, S, D). Group-local capacity-padded dispatch.

    decode=True routes plain top-k with capacity == T (no truncation):
    capacity coupling across tokens would make decode disagree with the
    batched forward pass, and at serve time balance is a latency concern,
    not a correctness one.
    """
    import functools
    import math
    e = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, k = e.n_experts, e.top_k
    G = math.gcd(shd.data_groups, T)
    Tg = T // G
    if decode:
        capacity = Tg
    else:
        capacity = min(max(1, int(Tg * k / E * e.capacity_factor)), Tg)

    xt = x.reshape(G, Tg, D)
    xt = shd.constrain(xt, "batch", None, None)
    logits = (xt @ p["gate"][0]).astype(jnp.float32)   # (G, Tg, E)
    # Routing decisions are discrete: compute them under stop_gradient
    # (gradients reach the gate only through the combine softmax; this also
    # avoids differentiating through argsort/top_k, which this jaxlib
    # cannot transpose inside scan).
    logits_sg = jax.lax.stop_gradient(logits)

    # All groups' routing problems in ONE batched dispatch (the routers are
    # batch-polymorphic over the leading group axis).
    if e.router == "flow" and not decode:
        routing = auction_route(logits_sg, k, capacity, n_iters=e.router_iters)
    else:
        routing = topk_route(logits_sg, k, capacity)

    group_fn = functools.partial(_dispatch_group, p=p, cfg=cfg, k=k,
                                 capacity=capacity)
    out = jax.vmap(group_fn)(xt, routing.dispatch, logits)    # (G, Tg, D)
    out = shd.constrain(out, "batch", None, None)

    if e.n_shared:
        out = out + mlp_apply(p["shared"], xt, cfg, shd)
    out = out.reshape(B, S, D)
    return shd.constrain(out, "batch", None, None)


def moe_aux_metrics(p, x, cfg):
    """Load-balance diagnostics for benchmarks (not used in the loss)."""
    e = cfg.moe
    T = x.shape[0] * x.shape[1]
    logits = (x.reshape(T, -1) @ p["gate"][0]).astype(jnp.float32)
    capacity = max(1, int(T * e.top_k / e.n_experts * e.capacity_factor))
    r = (auction_route(logits, e.top_k, capacity) if e.router == "flow"
         else topk_route(logits, e.top_k, capacity))
    load = r.demand / jnp.maximum(1, jnp.sum(r.demand))
    return {"max_load": jnp.max(r.demand), "routed": jnp.sum(r.dispatch),
            "load_cv": jnp.std(load) / jnp.maximum(jnp.mean(load), 1e-9)}
