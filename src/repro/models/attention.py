"""Attention: GQA (+RoPE, qk-norm) and DeepSeek MLA, prefill + decode.

Memory discipline: prefill uses a flash-style online-softmax scan over key
chunks (never materializes S×S scores — mandatory at 32k+); decode scores
against the full cache (1×T per head is small). KV caches are sharded over
the *sequence* axis on the model mesh axis: XLA's SPMD partitioner turns the
softmax reductions into the flash-decoding split-KV collective pattern
automatically.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ParamFactory, Sharder, apply_rope, rmsnorm

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array           # GQA: (B, S, KV, dh) | MLA: c_kv (B, S, kv_lora)
    v: jax.Array           # GQA: (B, S, KV, dh) | MLA: k_rope (B, S, rope)
    length: jax.Array      # filled prefix length (scalar int32)


class KVCacheQ(NamedTuple):
    """int8-quantized GQA KV cache: halves decode's dominant HBM term.

    Per-vector symmetric scales (one f32 per (b, s, kv_head)); dequant
    happens next to the score einsum where the TPU fuses it into the
    matmul's operand read. Enabled by ``cfg.kv_quant``.
    """
    k_q: jax.Array         # (B, S, KV, dh) int8
    k_s: jax.Array         # (B, S, KV, 1) f32
    v_q: jax.Array         # (B, S, KV, dh) int8
    v_s: jax.Array         # (B, S, KV, 1) f32
    length: jax.Array


def _quant_kv(x):
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.
    q = jnp.round(x.astype(jnp.float32) /
                  jnp.maximum(s, 1e-9)).astype(jnp.int8)
    return q, s


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(pf: ParamFactory, path: str, cfg):
    dh, H, KV, D = cfg.dh, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    p = {
        "wq": pf.dense(f"{path}.wq", (D, H * dh), ("fsdp", "tp")),
        "wk": pf.dense(f"{path}.wk", (D, KV * dh), ("fsdp", "tp")),
        "wv": pf.dense(f"{path}.wv", (D, KV * dh), ("fsdp", "tp")),
        "wo": pf.dense(f"{path}.wo", (H * dh, D), ("tp", "fsdp"),
                       scale=(H * dh) ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qk_norm:
        p["q_g"] = pf.ones(f"{path}.q_g", (dh,), (None,))
        p["k_g"] = pf.ones(f"{path}.k_g", (dh,), (None,))
    return p


def _flash_fwd_scan(q, k, v, causal, scale, chunk):
    """Online-softmax forward. Returns (out32 (B,H,Sq,dv), lse (B,H,Sq)).

    Mixed precision, MXU-native: QK^T and PV dots run on bf16 operands with
    f32 accumulation (preferred_element_type); only the softmax statistics
    stay f32. Halves the dominant HBM traffic (scores/probs) and uses the
    MXU at full bf16 rate instead of 1/4-rate f32.
    """
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    n_chunks = Sk // chunk
    kc = k.reshape(B, n_chunks, chunk, KV, dh).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, chunk, KV, dv).swapaxes(0, 1)
    pos_q = jnp.arange(Sq)
    cdt = q.dtype if q.dtype == jnp.bfloat16 else jnp.float32

    def body(carry, inp):
        # NOTE: the chunk index lives in the CARRY, not in an arange xs:
        # as an xs-derived constant XLA pre-materializes every chunk's
        # broadcasted causal mask into one (n_chunks, B, H, Sq, C) buffer.
        acc, m, l, idx = carry
        kb, vb = inp
        kb = jnp.repeat(kb, G, axis=2).astype(cdt)
        vb = jnp.repeat(vb, G, axis=2).astype(cdt)
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(cdt), kb,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            # additive f32 penalty, NOT where(pred,...): the (Sq, C) penalty
            # is loop-invariant across layers, and a hoisted boolean
            # broadcast materializes (n_chunks, B, H, Sq, C) preds (2.4 GiB
            # per chip observed); the f32 add keeps the hoist at (Sq, C).
            pos_k = idx * chunk + jnp.arange(chunk)
            pen = jnp.where(pos_q[:, None] >= pos_k[None, :], 0.0, NEG_INF)
            s = s + pen[None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(cdt), vb,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new, idx + 1), None

    acc0 = jnp.zeros((B, H, Sq, dv), jnp.float32)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, l, _), _ = jax.lax.scan(
        body, (acc0, m0, l0, jnp.int32(0)), (kc, vc))
    l = jnp.maximum(l, 1e-30)
    return acc / l[..., None], m + jnp.log(l)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_core(q, k, v, causal: bool, scale: float, chunk: int):
    out, _ = _flash_fwd_scan(q, k, v, causal, scale, chunk)
    return out.swapaxes(1, 2).astype(q.dtype)


def _flash_core_fwd(q, k, v, causal, scale, chunk):
    out32, lse = _flash_fwd_scan(q, k, v, causal, scale, chunk)
    out = out32.swapaxes(1, 2).astype(q.dtype)
    return out, (q, k, v, out32, lse)


def _flash_core_bwd(causal, scale, chunk, res, dout):
    """Flash backward: recompute p per key chunk from (q,k,v,out,lse).

    Residual memory is O(B·H·S·dv) instead of the O(B·H·S²) a plain scan
    backward would save — this is what makes 32k prefill trainable.
    """
    q, k, v, out32, lse = res
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    n_chunks = Sk // chunk
    cdt = q.dtype if q.dtype == jnp.bfloat16 else jnp.float32
    qc = q.astype(cdt)
    do32 = dout.astype(jnp.float32).swapaxes(1, 2)        # (B,H,Sq,dv)
    doc = do32.astype(cdt)
    delta = jnp.sum(do32 * out32, axis=-1)                # (B,H,Sq)
    pos_q = jnp.arange(Sq)
    kc = k.reshape(B, n_chunks, chunk, KV, dh).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, chunk, KV, dv).swapaxes(0, 1)

    def body(carry, inp):
        dq, idx = carry
        kb, vb = inp
        kbf = jnp.repeat(kb, G, axis=2).astype(cdt)
        vbf = jnp.repeat(vb, G, axis=2).astype(cdt)
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, kbf,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            pos_k = idx * chunk + jnp.arange(chunk)
            pen = jnp.where(pos_q[:, None] >= pos_k[None, :], 0.0, NEG_INF)
            s = s + pen[None, None]
        p = jnp.exp(s - lse[..., None])                   # (B,H,Sq,C) f32
        pc = p.astype(cdt)
        dv_c = jnp.einsum("bhqk,bhqd->bkhd", pc, doc,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhqd,bkhd->bhqk", doc, vbf,
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - delta[..., None]) * scale).astype(cdt)
        dq = dq + jnp.einsum("bhqk,bkhd->bqhd", ds, kbf,
                             preferred_element_type=jnp.float32)
        dk_c = jnp.einsum("bhqk,bqhd->bkhd", ds, qc,
                          preferred_element_type=jnp.float32)
        dk_c = dk_c.reshape(B, chunk, KV, G, dh).sum(3)
        dv_c = dv_c.reshape(B, chunk, KV, G, dv).sum(3)
        return (dq, idx + 1), (dk_c, dv_c)

    dq0 = jnp.zeros((B, Sq, H, dh), jnp.float32)
    (dq, _), (dk_c, dv_c) = jax.lax.scan(
        body, (dq0, jnp.int32(0)), (kc, vc))
    dk = dk_c.swapaxes(0, 1).reshape(B, Sk, KV, dh)
    dv_full = dv_c.swapaxes(0, 1).reshape(B, Sk, KV, dv)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv_full.astype(v.dtype)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def _flash_attend(q, k, v, *, causal: bool, scale: float, chunk: int):
    """Flash attention (custom VJP). q: (B,Sq,H,dh); k/v: (B,Sk,KV,·)."""
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    assert Sk % chunk == 0, (Sk, chunk)
    return _flash_core(q, k, v, causal, scale, chunk)


def gqa_apply(p, x, cfg, shd: Sharder, *,
              positions, cache: Optional[KVCache] = None, decode: bool,
              kv_chunk: int = 512):
    """Returns (out, new_cache). Training/prefill: decode=False."""
    B, S, D = x.shape
    dh, H, KV = cfg.dh, cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"][0]).reshape(B, S, H, dh)
    k = (x @ p["wk"][0]).reshape(B, S, KV, dh)
    v = (x @ p["wv"][0]).reshape(B, S, KV, dh)
    if cfg.qk_norm:
        q, k = rmsnorm(q, p["q_g"][0]), rmsnorm(k, p["k_g"][0])
    if cfg.rope_theta:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shd.constrain(q, "batch", None, "tp", None)
    scale = dh ** -0.5

    quant = isinstance(cache, KVCacheQ)
    if decode:
        assert cache is not None and S == 1
        if quant:
            kq, ks = _quant_kv(k)
            vq, vs = _quant_kv(v)
            kcq = jax.lax.dynamic_update_slice(
                cache.k_q, kq, (0, cache.length, 0, 0))
            kcs = jax.lax.dynamic_update_slice(
                cache.k_s, ks, (0, cache.length, 0, 0))
            vcq = jax.lax.dynamic_update_slice(
                cache.v_q, vq, (0, cache.length, 0, 0))
            vcs = jax.lax.dynamic_update_slice(
                cache.v_s, vs, (0, cache.length, 0, 0))
            kcq = shd.constrain(kcq, "batch", "seq", None, None)
            vcq = shd.constrain(vcq, "batch", "seq", None, None)
            kc = kcq.astype(jnp.float32) * kcs    # fused into score read
            vc = vcq.astype(jnp.float32) * vcs
            new_cache = KVCacheQ(kcq, kcs, vcq, vcs, cache.length + 1)
        else:
            kc = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, cache.length, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, cache.length, 0, 0))
            kc = shd.constrain(kc, "batch", "seq", None, None)
            vc = shd.constrain(vc, "batch", "seq", None, None)
            new_cache = KVCache(kc, vc, cache.length + 1)
        T = kc.shape[1]
        G = H // KV
        # grouped decode score: q reshaped to (B, 1, KV, G, dh)
        qg = q.astype(jnp.float32).reshape(B, 1, KV, G, dh)
        s = jnp.einsum("bqkgd,btkd->bkgqt", qg, kc.astype(jnp.float32))
        s = s * scale
        valid = jnp.arange(T) <= cache.length     # includes the new token
        s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqt,btkd->bqkgd", pr, vc.astype(jnp.float32))
        o = o.reshape(B, 1, H * dh).astype(x.dtype)
    else:
        o = _flash_attend(q, k, v, causal=cfg.causal, scale=scale,
                          chunk=kv_chunk).reshape(B, S, H * dh)
        if cache is None:
            new_cache = None
        elif quant:             # prefill: quantize the whole prefix
            kq, ks = _quant_kv(k)
            vq, vs = _quant_kv(v)
            new_cache = KVCacheQ(
                jax.lax.dynamic_update_slice(cache.k_q, kq, (0, 0, 0, 0)),
                jax.lax.dynamic_update_slice(cache.k_s, ks, (0, 0, 0, 0)),
                jax.lax.dynamic_update_slice(cache.v_q, vq, (0, 0, 0, 0)),
                jax.lax.dynamic_update_slice(cache.v_s, vs, (0, 0, 0, 0)),
                jnp.int32(S))
        else:                   # prefill: write into the S_max buffer
            kc = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0))
            new_cache = KVCache(kc, vc, jnp.int32(S))
    out = o @ p["wo"][0]
    return shd.constrain(out, "batch", None, None), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank KV compression; absorbed decode
# ---------------------------------------------------------------------------

def init_mla(pf: ParamFactory, path: str, cfg):
    m, D, H = cfg.mla, cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": pf.dense(f"{path}.wq_a", (D, m.q_lora_rank), ("fsdp", None)),
        "q_norm": pf.ones(f"{path}.q_norm", (m.q_lora_rank,), (None,)),
        "wq_b": pf.dense(f"{path}.wq_b", (m.q_lora_rank, H * qd),
                         (None, "tp")),
        "wkv_a": pf.dense(f"{path}.wkv_a",
                          (D, m.kv_lora_rank + m.qk_rope_dim),
                          ("fsdp", None)),
        "kv_norm": pf.ones(f"{path}.kv_norm", (m.kv_lora_rank,), (None,)),
        "wk_b": pf.dense(f"{path}.wk_b", (m.kv_lora_rank, H, m.qk_nope_dim),
                         (None, "tp", None)),
        "wv_b": pf.dense(f"{path}.wv_b", (m.kv_lora_rank, H, m.v_dim),
                         (None, "tp", None)),
        "wo": pf.dense(f"{path}.wo", (H * m.v_dim, D), ("tp", "fsdp"),
                       scale=(H * m.v_dim) ** -0.5 / (2 * cfg.n_layers) ** .5),
    }


def mla_apply(p, x, cfg, shd: Sharder, *,
              positions, cache: Optional[KVCache] = None, decode: bool,
              kv_chunk: int = 512):
    m, H = cfg.mla, cfg.n_heads
    B, S, D = x.shape
    nope, rope, vd = m.qk_nope_dim, m.qk_rope_dim, m.v_dim
    scale = (nope + rope) ** -0.5

    q = rmsnorm(x @ p["wq_a"][0], p["q_norm"][0]) @ p["wq_b"][0]
    q = q.reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"][0]                      # (B, S, kv_lora + rope)
    c_kv = rmsnorm(kv_a[..., :m.kv_lora_rank], p["kv_norm"][0])
    k_rope = apply_rope(kv_a[..., None, m.kv_lora_rank:],
                        positions, cfg.rope_theta)   # (B, S, 1, rope)

    if decode:
        assert cache is not None and S == 1
        ckv = jax.lax.dynamic_update_slice(
            cache.k, c_kv.astype(cache.k.dtype), (0, cache.length, 0))
        krc = jax.lax.dynamic_update_slice(
            cache.v, k_rope[:, :, 0].astype(cache.v.dtype),
            (0, cache.length, 0))
        ckv = shd.constrain(ckv, "batch", "seq", None)
        krc = shd.constrain(krc, "batch", "seq", None)
        T = ckv.shape[1]
        # absorbed attention: score against the compressed cache directly
        q_abs = jnp.einsum("bqhn,khn->bqhk", q_nope.astype(jnp.float32),
                           p["wk_b"][0].astype(jnp.float32))
        s = jnp.einsum("bqhk,btk->bhqt", q_abs, ckv.astype(jnp.float32)) + \
            jnp.einsum("bqhr,btr->bhqt", q_rope.astype(jnp.float32),
                       krc.astype(jnp.float32))
        s = s * scale
        valid = jnp.arange(T) <= cache.length
        pr = jax.nn.softmax(
            jnp.where(valid[None, None, None, :], s, NEG_INF), axis=-1)
        ctx = jnp.einsum("bhqt,btk->bqhk", pr, ckv.astype(jnp.float32))
        o = jnp.einsum("bqhk,khv->bqhv", ctx,
                       p["wv_b"][0].astype(jnp.float32))
        o = o.reshape(B, 1, H * vd).astype(x.dtype)
        new_cache = KVCache(ckv, krc, cache.length + 1)
    else:
        # prefill/train: expand per-head K/V (standard MLA formulation)
        k_nope = jnp.einsum("btk,khn->bthn", c_kv, p["wk_b"][0])
        v = jnp.einsum("btk,khv->bthv", c_kv, p["wv_b"][0])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = _flash_attend(qf, k, v, causal=cfg.causal, scale=scale,
                          chunk=kv_chunk).reshape(B, S, H * vd)
        if cache is not None:   # prefill: write into the S_max buffer
            ckv = jax.lax.dynamic_update_slice(
                cache.k, c_kv.astype(cache.k.dtype), (0, 0, 0))
            krc = jax.lax.dynamic_update_slice(
                cache.v, k_rope[:, :, 0].astype(cache.v.dtype), (0, 0, 0))
            new_cache = KVCache(ckv, krc, jnp.int32(S))
        else:
            new_cache = None
    out = o @ p["wo"][0]
    return shd.constrain(out, "batch", None, None), new_cache
