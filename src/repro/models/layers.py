"""Shared model building blocks: params, sharding annotations, norms, RoPE."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Sharding: params/activations carry per-dim logical axes ("fsdp", "tp",
# "batch", "seq", None). A Sharder maps logical -> physical mesh axes and
# silently replicates any dim whose size does not divide the mesh axis
# (e.g. smollm's 9 heads over a 16-way model axis).
# ---------------------------------------------------------------------------

DEFAULT_RULES = {
    "fsdp": ("data",),
    "tp": ("model",),
    "batch": ("pod", "data"),   # pod axis folds into data parallelism
    "seq": ("model",),          # sequence sharding for KV caches / long ctx
}


@dataclasses.dataclass(frozen=True)
class Sharder:
    mesh: Any = None            # jax Mesh or None (single-device smoke tests)
    rules: Any = None

    def _axes(self, logical: str | None, size: int):
        if self.mesh is None or logical is None:
            return None
        axes = tuple(a for a in (self.rules or DEFAULT_RULES).get(logical, ())
                     if a in self.mesh.shape)
        if not axes:
            return None
        total = 1
        for a in axes:
            total *= self.mesh.shape[a]
        if size % total != 0:
            return None             # replicate: not evenly divisible
        return axes if len(axes) > 1 else axes[0]

    def spec(self, shape, logical) -> P:
        assert len(shape) == len(logical), (shape, logical)
        return P(*(self._axes(l, s) for s, l in zip(shape, logical)))

    def constrain(self, x, *logical):
        """with_sharding_constraint by logical dim names (no-op w/o mesh)."""
        if self.mesh is None:
            return x
        sh = NamedSharding(self.mesh, self.spec(x.shape, logical))
        return jax.lax.with_sharding_constraint(x, sh)

    @property
    def data_groups(self) -> int:
        """Number of data-parallel shards (the MoE dispatch group count).

        Sort/scatter token dispatch must stay LOCAL to a data shard: a
        global argsort cannot be partitioned and makes XLA replicate
        (tokens × d_model) tensors across the mesh (observed: 55 TB/device
        of all-reduce on deepseek train_4k). Grouping by this count and
        vmapping keeps every dispatch op shard-local.
        """
        if self.mesh is None:
            return 1
        n = 1
        for a in (self.rules or DEFAULT_RULES).get("batch", ()):
            if a in self.mesh.shape:
                n *= self.mesh.shape[a]
        return n


# ---------------------------------------------------------------------------
# Parameter trees: each leaf is a dict entry; a parallel tree of logical axes
# is built at init so dryrun/train can derive PartitionSpecs without guessing.
# ---------------------------------------------------------------------------

class ParamFactory:
    """Collects params + their logical axes; deterministic per-path init."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self.key = key
        self.dtype = dtype

    def _fold(self, path: str) -> jax.Array:
        import zlib  # crc32: stable across processes (unlike str hash)
        h = jnp.uint32(zlib.crc32(path.encode()) & 0x7FFFFFFF)
        return jax.random.fold_in(self.key, h)

    def dense(self, path: str, shape, logical, scale: float | None = None):
        fan_in = shape[0] if len(shape) > 1 else shape[-1]
        std = scale if scale is not None else fan_in ** -0.5
        w = jax.random.normal(self._fold(path), shape, self.dtype) * std
        return w, tuple(logical)

    def zeros(self, path: str, shape, logical):
        return jnp.zeros(shape, self.dtype), tuple(logical)

    def ones(self, path: str, shape, logical):
        return jnp.ones(shape, self.dtype), tuple(logical)


def split_tree(tree):
    """Split a tree of (param, logical) leaves into (params, logical_axes)."""
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(
        x[1], tuple) and all(isinstance(a, (str, type(None))) for a in x[1])
    params = jax.tree.map(lambda x: x[0], tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda x: x[1], tree, is_leaf=is_leaf)
    return params, axes


def stack_layer_trees(trees):
    """Stack per-layer param trees along a new leading (scan) dimension."""
    params = [t[0] for t in trees]
    axes = trees[0][1]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *params)
    axes = jax.tree.map(
        lambda a: (None,) + a,
        axes, is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(v, (str, type(None))) for v in x))
    return stacked, axes


# ---------------------------------------------------------------------------
# Norms / activations / rotary embeddings
# ---------------------------------------------------------------------------

def rmsnorm(x, g, eps=1e-6):
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (x32 * scale).astype(x.dtype) * g


def layernorm(x, g, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, -1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g + b


def relu2(x):
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS: dict[str, Callable] = {
    "relu2": relu2,          # nemotron/minitron squared-ReLU
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def apply_rope(x, positions, theta: float = 10_000.0):
    """Table-free RoPE. x: (B, S, H, D); positions: (S,) int.

    Frequencies are computed from positions directly — no (max_seq, D/2)
    table, which matters at 524k context (and keeps seq-sharding local).
    """
    d = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    f = positions.astype(jnp.float32)[:, None] * inv[None, :]   # (S, D/2)
    c = jnp.cos(f)[None, :, None, :]
    s = jnp.sin(f)[None, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


def sinusoidal_pos(positions, d_model: int):
    """Encoder positional embedding (stub for HuBERT's conv-pos frontend)."""
    half = d_model // 2
    inv = 1.0 / (10_000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    f = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(f), jnp.cos(f)], axis=-1)


def softmax_cross_entropy(logits, labels, z_loss: float = 0.0):
    """Token CE with optional z-loss; logits f32-reduced.

    The label logit is extracted with a masked reduction, NOT
    take_along_axis: a vocab-sharded gather makes the SPMD partitioner
    all-gather the full (B, S, V) logits (observed: 30 GiB/chip on the
    135M dry-run); the masked sum partitions cleanly.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                 axis=-1)
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse ** 2
    return loss
