"""LM assembly: layer plan -> scan-over-periods -> logits, for all families.

Heterogeneous stacks (jamba's 1:7 attn:mamba interleave with alternating MoE,
deepseek's dense-FFN first layer) are handled by finding the repeating
*period* of the layer plan: the period's sublayers are unrolled inside the
scan body, the scan runs over stacked period parameters. This keeps the HLO
size O(period) instead of O(n_layers) — essential for 96-layer dry-runs.

Convention: module ``init_*`` functions return trees whose leaves are
``(array, logical_axes)`` pairs; ``split_tree`` separates them into a params
tree (arrays) and an axes tree (tuples) at the top level. ``apply_model``
takes both and re-pairs lazily (axes are static, so they are closed over —
never traced through ``lax.scan``).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models.attention import KVCache, KVCacheQ
from repro.models.mamba import SSMCache
from repro.models.layers import (ParamFactory, Sharder, layernorm, rmsnorm,
                                 sinusoidal_pos, split_tree)


def _is_axes(x):
    return isinstance(x, tuple) and all(
        isinstance(v, (str, type(None))) for v in x)


def _is_pair(x):
    return isinstance(x, tuple) and len(x) == 2 and _is_axes(x[1])


def zip_axes(params, axes):
    """Re-pair a params tree with its (static) logical-axes tree."""
    leaves, treedef = jax.tree.flatten(params)
    alist = treedef.flatten_up_to(axes)
    return jax.tree.unflatten(treedef, list(zip(leaves, alist)))


def stack_pair_trees(trees):
    """Stack per-period pair-trees along a new leading (scan) axis."""
    def stack(*leaves):
        return (jnp.stack([l[0] for l in leaves], 0),
                (None,) + leaves[0][1])
    return jax.tree.map(stack, *trees, is_leaf=_is_pair)


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig) -> list[tuple[str, str | None]]:
    plan = []
    for i in range(cfg.n_layers):
        if cfg.family == "ssm":
            mixer = "mamba"
        elif cfg.family == "hybrid":
            mixer = "attn" if i % cfg.attn_period == 0 else "mamba"
        else:
            mixer = "attn"
        if (cfg.moe is not None and i >= cfg.n_dense_prefix
                and (i - cfg.n_dense_prefix) % cfg.moe.every == 0):
            ffn = "moe"
        elif cfg.d_ff:
            ffn = "mlp"
        else:
            ffn = None
        plan.append((mixer, ffn))
    return plan


def plan_period(cfg: ModelConfig) -> int:
    period = cfg.attn_period if cfg.family == "hybrid" else 1
    if cfg.moe is not None:
        period = math.lcm(period, cfg.moe.every)
    assert (cfg.n_layers - cfg.n_dense_prefix) % period == 0, cfg.name
    return period


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_norm(pf, path, cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"g": pf.ones(f"{path}.g", (d,), (None,)),
                "b": pf.zeros(f"{path}.b", (d,), (None,))}
    return {"g": pf.ones(f"{path}.g", (d,), (None,))}


def _apply_norm(p, x, cfg):
    if cfg.norm == "layernorm":
        return layernorm(x, p["g"][0], p["b"][0])
    return rmsnorm(x, p["g"][0])


def _init_sublayer(pf, path, cfg, spec):
    mixer, ffn = spec
    p: dict[str, Any] = {"norm1": _init_norm(pf, f"{path}.norm1", cfg)}
    if mixer == "attn":
        init = attn_mod.init_mla if cfg.attn_type == "mla" \
            else attn_mod.init_gqa
        p["mixer"] = init(pf, f"{path}.attn", cfg)
    else:
        p["mixer"] = mamba_mod.init_mamba(pf, f"{path}.mamba", cfg)
    if ffn:
        p["norm2"] = _init_norm(pf, f"{path}.norm2", cfg)
        p["ffn"] = (mlp_mod.init_moe if ffn == "moe" else mlp_mod.init_mlp)(
            pf, f"{path}.{ffn}", cfg)
    return p


def init_model(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    """Returns (params, logical_axes): two aligned trees of plain leaves."""
    pf = ParamFactory(key, dtype)
    plan = layer_plan(cfg)
    period = plan_period(cfg)
    n_periods = (cfg.n_layers - cfg.n_dense_prefix) // period

    tree: dict[str, Any] = {}
    if cfg.frontend_dim:
        tree["frontend"] = pf.dense(
            "frontend", (cfg.frontend_dim, cfg.d_model), (None, "fsdp"))
    # d^-0.5 embedding scale keeps tied-head logits ~N(0,1) at init
    # (scale=1.0 gave init CE ~100 instead of ln V on tied archs)
    tree["embed"] = pf.dense("embed", (cfg.vocab, cfg.d_model),
                             ("tp", "fsdp"), scale=cfg.d_model ** -0.5)
    tree["prefix"] = [
        _init_sublayer(pf, f"prefix{i}", cfg, plan[i])
        for i in range(cfg.n_dense_prefix)]
    period_trees = [
        {f"sub{j}": _init_sublayer(
            pf, f"body{r}.sub{j}", cfg, plan[cfg.n_dense_prefix + j])
         for j in range(period)}
        for r in range(n_periods)]
    tree["body"] = stack_pair_trees(period_trees)
    tree["final_norm"] = _init_norm(pf, "final_norm", cfg)
    if not cfg.tie_embeddings:
        tree["lm_head"] = pf.dense("lm_head", (cfg.d_model, cfg.vocab),
                                   ("fsdp", "tp"))
    return split_tree(tree)


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

class ModelOutput(NamedTuple):
    logits: jax.Array
    caches: Any


def _apply_sublayer(p, x, cfg, shd, spec, *, positions, cache, decode):
    mixer, ffn = spec
    h = _apply_norm(p["norm1"], x, cfg)
    if mixer == "attn":
        fn = attn_mod.mla_apply if cfg.attn_type == "mla" \
            else attn_mod.gqa_apply
        mo, new_cache = fn(p["mixer"], h, cfg, shd, positions=positions,
                           cache=cache, decode=decode)
    else:
        mo, new_cache = mamba_mod.mamba_apply(p["mixer"], h, cfg, shd,
                                              cache=cache, decode=decode)
    x = x + mo
    if ffn == "moe":
        h = _apply_norm(p["norm2"], x, cfg)
        x = x + mlp_mod.moe_apply(p["ffn"], h, cfg, shd, decode=decode)
    elif ffn == "mlp":
        h = _apply_norm(p["norm2"], x, cfg)
        x = x + mlp_mod.mlp_apply(p["ffn"], h, cfg, shd)
    return x, new_cache


def apply_model(params, axes, cfg: ModelConfig, shd: Sharder, batch,
                *, caches=None, decode: bool = False, pos_offset=0,
                logits_mode: str = "all") -> ModelOutput:
    """batch: {"tokens": (B,S) int} or {"embeds": (B,S,frontend_dim)}."""
    plan = layer_plan(cfg)
    period = plan_period(cfg)
    pairs = zip_axes(params, axes)            # top-level lazy pairing

    if cfg.frontend_dim:
        x = batch["embeds"].astype(pairs["frontend"][0].dtype) \
            @ pairs["frontend"][0]
    else:
        x = jnp.take(pairs["embed"][0], batch["tokens"], axis=0)
    x = shd.constrain(x, "batch", None, None)
    S = x.shape[1]
    positions = pos_offset + jnp.arange(S)
    if not cfg.causal and not cfg.rope_theta:
        x = x + sinusoidal_pos(positions, cfg.d_model)[None].astype(x.dtype)

    new_prefix_caches = []
    for i in range(cfg.n_dense_prefix):
        c = caches["prefix"][i] if caches else None
        x, nc = _apply_sublayer(pairs["prefix"][i], x, cfg, shd, plan[i],
                                positions=positions, cache=c, decode=decode)
        new_prefix_caches.append(nc)

    body_specs = [plan[cfg.n_dense_prefix + j] for j in range(period)]
    body_axes_inner = jax.tree.map(lambda a: a[1:], axes["body"],
                                   is_leaf=_is_axes)

    def body_fn(x, scanned):
        pp_arrays, cc = scanned
        pp = zip_axes(pp_arrays, body_axes_inner)
        new_cc = []
        for j in range(period):
            cj = cc[j] if cc is not None else None
            x, ncj = _apply_sublayer(pp[f"sub{j}"], x, cfg, shd,
                                     body_specs[j], positions=positions,
                                     cache=cj, decode=decode)
            new_cc.append(ncj)
        return x, (tuple(new_cc) if cc is not None else None)

    if cfg.remat == "full":
        body_fn = jax.checkpoint(
            body_fn, policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        body_fn = jax.checkpoint(
            body_fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    body_caches = caches["body"] if caches else None
    x, new_body_caches = jax.lax.scan(
        body_fn, x, (params["body"], body_caches))

    x = _apply_norm(pairs["final_norm"], x, cfg)
    if logits_mode == "last":
        x = x[:, -1:]
    if cfg.tie_embeddings:
        logits = x @ pairs["embed"][0].T
    else:
        logits = x @ pairs["lm_head"][0]
    logits = shd.constrain(logits, "batch", None, "tp")
    new_caches = {"prefix": new_prefix_caches, "body": new_body_caches} \
        if caches is not None else None
    return ModelOutput(logits, new_caches)


# ---------------------------------------------------------------------------
# Caches (layout + logical axes, mirrored trees)
# ---------------------------------------------------------------------------

def _layer_cache(cfg, spec, B, S_max, dtype):
    """Returns (cache, logical) — aligned NamedTuples."""
    mixer, _ = spec
    if mixer == "attn":
        if cfg.attn_type == "mla":
            m = cfg.mla
            c = KVCache(jnp.zeros((B, S_max, m.kv_lora_rank), dtype),
                        jnp.zeros((B, S_max, m.qk_rope_dim), dtype),
                        jnp.int32(0))
            a = KVCache(("batch", "seq", None), ("batch", "seq", None), ())
        elif cfg.kv_quant:
            c = KVCacheQ(
                jnp.zeros((B, S_max, cfg.n_kv_heads, cfg.dh), jnp.int8),
                jnp.zeros((B, S_max, cfg.n_kv_heads, 1), jnp.float32),
                jnp.zeros((B, S_max, cfg.n_kv_heads, cfg.dh), jnp.int8),
                jnp.zeros((B, S_max, cfg.n_kv_heads, 1), jnp.float32),
                jnp.int32(0))
            a = KVCacheQ(("batch", "seq", None, None),
                         ("batch", "seq", None, None),
                         ("batch", "seq", None, None),
                         ("batch", "seq", None, None), ())
        else:
            c = KVCache(
                jnp.zeros((B, S_max, cfg.n_kv_heads, cfg.dh), dtype),
                jnp.zeros((B, S_max, cfg.n_kv_heads, cfg.dh), dtype),
                jnp.int32(0))
            a = KVCache(("batch", "seq", None, None),
                        ("batch", "seq", None, None), ())
        return c, a
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    c = SSMCache(
        jnp.zeros((B, s.n_heads(cfg.d_model), s.head_dim, s.d_state),
                  jnp.float32),
        jnp.zeros((B, s.d_conv - 1, di + 2 * s.d_state), dtype),
        jnp.int32(0))
    a = SSMCache(("batch", None, None, None), ("batch", None, "tp"), ())
    return c, a


def init_caches(cfg: ModelConfig, B: int, S_max: int, dtype=jnp.bfloat16):
    """Returns (caches, logical_axes) — aligned trees."""
    plan = layer_plan(cfg)
    period = plan_period(cfg)
    n_periods = (cfg.n_layers - cfg.n_dense_prefix) // period
    prefix, prefix_a = [], []
    for i in range(cfg.n_dense_prefix):
        c, a = _layer_cache(cfg, plan[i], B, S_max, dtype)
        prefix.append(c)
        prefix_a.append(a)
    per, per_a = [], []
    for j in range(period):
        c, a = _layer_cache(cfg, plan[cfg.n_dense_prefix + j], B, S_max,
                            dtype)
        per.append(c)
        per_a.append(a)
    body = jax.tree.map(
        lambda x: jnp.zeros((n_periods,) + x.shape, x.dtype), tuple(per))
    body_a = jax.tree.map(lambda a: (None,) + a if a else (None,),
                          tuple(per_a), is_leaf=_is_axes)
    return ({"prefix": prefix, "body": body},
            {"prefix": prefix_a, "body": body_a})


def cache_specs(cfg, shd: Sharder, caches, cache_axes):
    """PartitionSpec tree for a cache tree."""
    leaves, treedef = jax.tree.flatten(caches)
    alist = treedef.flatten_up_to(cache_axes)
    return jax.tree.unflatten(
        treedef,
        [shd.spec(l.shape, a) for l, a in zip(leaves, alist)])
