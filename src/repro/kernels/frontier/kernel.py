"""Pallas TPU kernel: one BFS frontier-expansion sweep of bipartite matching.

The compute hot-spot of the lock-free alternating-BFS phase (Deveci et al.,
arXiv:1303.1379): every column scans its adjacent LABELED rows and is
claimed by the strongest candidate.  On the GPU the paper resolves the race
with atomics; here the claim rule is a deterministic keyed minimum — a
column takes the smallest root label among labeled rows reaching it over
non-matching edges, tie-broken by smallest row index — so the "winner" of
the race is a pure reduction and bit-stable across batching/sharding
layouts.

Per column ``j`` the kernel reduces, over rows ``i`` with
``adj[i, j] & (root_row[i] < INF) & (match_row[i] != j)``:

  * ``min_root[j]``  — the minimum ``root_row[i]`` (INF if no candidate),
  * ``claim_row[j]`` — the minimum ``i`` attaining that minimum root.

Tiling: grid = (n_cols/BC, n_rows/BR); the ROW dimension is innermost so
each column-block's (min_root, claim_row) accumulator stays resident in its
output VMEM block across the whole row sweep (the same streaming-reduction
shape as the bidding kernel, transposed).  Row blocks arrive in increasing
``i``, so keeping the incumbent on ties preserves the min-row tie-break.
VMEM working set per grid step = BR·BC (adj) + 2·BR·4B (row labels)
+ 2·BC·4B (accumulators) — far below the 16 MB budget at BR=256, BC=512.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = 2 ** 30  # python int: jnp scalars would be captured consts in pallas


def _frontier_kernel(a_ref, r_ref, m_ref, root_ref, claim_ref, *,
                     block_rows: int, block_cols: int):
    j = pl.program_id(0)
    i = pl.program_id(1)

    a = a_ref[...]                       # (BR, BC) bool adjacency tile
    root = r_ref[...]                    # (BR, 1) int32 row root labels
    match = m_ref[...]                   # (BR, 1) int32 matched col (-1 free)

    cols = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1) + j * block_cols
    rows = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0) + i * block_rows
    cand = jnp.where(a & (root < INF) & (match != cols), root, INF)

    # local keyed min along the tile's rows: (root, then row index)
    l_root = jnp.min(cand, axis=0, keepdims=True)                  # (1, BC)
    l_claim = jnp.min(jnp.where(cand == l_root, rows, INF), axis=0,
                      keepdims=True)

    @pl.when(i == 0)
    def _init():
        root_ref[...] = l_root
        claim_ref[...] = l_claim

    @pl.when(i > 0)
    def _merge():
        r_root, r_claim = root_ref[...], claim_ref[...]
        # strict <: on a root tie the incumbent block holds smaller rows
        take_new = l_root < r_root
        root_ref[...] = jnp.where(take_new, l_root, r_root)
        claim_ref[...] = jnp.where(take_new, l_claim, r_claim)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols",
                                             "interpret"))
def frontier(adj: jax.Array, root_row: jax.Array, match_row: jax.Array,
             *, block_rows: int = 256, block_cols: int = 512,
             interpret: bool = True):
    """Per-column ``(min_root, claim_row)`` over labeled candidate rows.

    ``adj`` is ``(n_r, n_c)`` bool; ``root_row``/``match_row`` are
    ``(n_r,)`` int32 (root INF = unlabeled, match -1 = free).  Columns with
    no candidate return ``(INF, 0)`` — callers gate on ``min_root < INF``.
    interpret=True executes the kernel body on CPU (validation mode); on a
    real TPU pass interpret=False.
    """
    n_r, n_c = adj.shape
    br, bc = min(block_rows, n_r), min(block_cols, n_c)
    assert n_r % br == 0 and n_c % bc == 0, (n_r, n_c, br, bc)
    grid = (n_c // bc, n_r // br)

    out_shape = [jax.ShapeDtypeStruct((1, n_c), jnp.int32)] * 2
    out_spec = pl.BlockSpec((1, bc), lambda j, i: (0, j))
    col_spec = pl.BlockSpec((br, 1), lambda j, i: (i, 0))
    min_root, claim_row = pl.pallas_call(
        functools.partial(_frontier_kernel, block_rows=br, block_cols=bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda j, i: (i, j)),
            col_spec,
            col_spec,
        ],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(adj, root_row.reshape(-1, 1).astype(jnp.int32),
      match_row.reshape(-1, 1).astype(jnp.int32))
    return min_root[0], claim_row[0]
