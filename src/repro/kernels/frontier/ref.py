"""Pure-jnp oracle for the frontier-expansion kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.int32(2 ** 30)


def frontier_ref(adj, root_row, match_row):
    """Per-column keyed min over labeled candidate rows (see kernel.py)."""
    n_r, n_c = adj.shape
    cols = jnp.arange(n_c, dtype=jnp.int32)[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n_r, n_c), 0)
    cand = jnp.where(
        adj & (root_row[:, None] < INF) & (match_row[:, None] != cols),
        root_row[:, None].astype(jnp.int32), INF)
    min_root = jnp.min(cand, axis=0)
    claim_row = jnp.min(jnp.where(cand == min_root[None, :], rows, INF),
                        axis=0)
    return min_root, claim_row
