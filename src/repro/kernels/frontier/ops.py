"""Jit'd public wrapper for the frontier kernel (auto interpret on CPU)."""
from __future__ import annotations

import jax

from repro.kernels.frontier.kernel import frontier
from repro.kernels.frontier.ref import frontier_ref  # noqa: F401  (oracle)


def frontier_op(adj, root_row, match_row, *, block_rows: int = 256,
                block_cols: int = 512):
    interpret = jax.default_backend() != "tpu"
    return frontier(adj, root_row, match_row, block_rows=block_rows,
                    block_cols=block_cols, interpret=interpret)
