"""Pallas TPU kernel: bidirectional BFS wavefront sweeps for global relabel.

The paper's global relabeling heuristic (Alg. 4.4) is a backward BFS from
the sink; its gap relabel (§4.6) lifts unreached nodes to N. The XLA
implementation (``repro.core.maxflow.grid.bfs_heights``) runs ONE min-plus
relaxation sweep per ``while_loop`` iteration — every sweep is a full HBM
round trip over all five planes. This kernel is the workload-balanced
backend's replacement (cf. arXiv 2404.00270's kernel-resident global
relabel): it keeps the wavefront planes VMEM-resident and runs ``SWEEPS``
relaxation sweeps per invocation, so the fixpoint driver (ops.py) touches
HBM once per ``SWEEPS`` sweeps instead of once per sweep.

Two wavefronts relax simultaneously (both follow residual OUT-edges, so
they share one sweep loop):

* ``dt`` — height-to-sink: seeded 1 where residual x→t exists; the paper's
  Alg. 4.4 labeling.
* ``ds`` — height-via-source: seeded N+1 where residual x→s exists (a node
  at N+1 pushes to the source, whose conceptual height is N); the RETURN
  path labeling the paper leaves to slow +1 relabels. Baumstark et al.
  (arXiv 1507.01926) relabel from both terminals for exactly this reason.

The combine (``dt`` if reached, else ``max(h_prev, ds)``, else
``max(h_prev, N)``) happens in ops.py AFTER the joint fixpoint — combining
early would leak not-yet-converged ``ds`` values into the sink labeling.

Blocks are whole (H, W) planes with a batch grid dimension — wavefronts
cross the entire grid, so tiling would reintroduce a halo fixpoint per
sweep. VMEM per step: 4 cap planes + 2 seed planes + 2 in + 2 out
wavefront planes = 10 planes of H·W·4B; 256² ⇒ ~2.6 MB, comfortably
within VMEM. Grids beyond ~512² need a tiled variant (not needed here:
the solvers top out at vision-scale 256² instances).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF_H = 2 ** 30  # python int: jnp scalars would be captured consts in pallas

# Relaxation sweeps per kernel invocation. Each extra sweep is pure VMEM
# work; the fixpoint driver rounds its iteration budget up to a multiple
# of this. 8 amortizes the HBM round trip without inflating the tail
# (converged planes re-relax as no-ops).
SWEEPS = 8


def _shift_min(a, d):
    """min-plus neighbour gather: value of a at x's neighbour in dir d.

    Mirrors ``grid._nbr_h`` (UP, DOWN, LEFT, RIGHT = 0..3) with INF fill
    outside the grid, on concrete (H, W) values inside the kernel.
    """
    big = jnp.full_like(a[:1, :], INF_H)
    bigc = jnp.full_like(a[:, :1], INF_H)
    if d == 0:    # UP
        return jnp.concatenate([big, a[:-1, :]], axis=0)
    if d == 1:    # DOWN
        return jnp.concatenate([a[1:, :], big], axis=0)
    if d == 2:    # LEFT
        return jnp.concatenate([bigc, a[:, :-1]], axis=1)
    return jnp.concatenate([a[:, 1:], bigc], axis=1)


def _bfs_relabel_kernel(cap_ref, seed_t_ref, seed_s_ref, dt_ref, ds_ref,
                        dt_out_ref, ds_out_ref):
    bh, bw = dt_ref.shape[-2:]
    cap = cap_ref[...].reshape(4, bh, bw)      # f32 residual neighbour caps
    seed_t = seed_t_ref[...].reshape(bh, bw)   # i32: 1 | INF
    seed_s = seed_s_ref[...].reshape(bh, bw)   # i32: N+1 | INF
    dt = dt_ref[...].reshape(bh, bw)
    ds = ds_ref[...].reshape(bh, bw)

    def sweep(_, carry):
        dt, ds = carry
        rt, rs = dt, ds
        for d in range(4):
            open_edge = cap[d] > 0
            rt = jnp.minimum(rt, jnp.where(open_edge,
                                           _shift_min(dt, d) + 1, INF_H))
            rs = jnp.minimum(rs, jnp.where(open_edge,
                                           _shift_min(ds, d) + 1, INF_H))
        return jnp.minimum(rt, seed_t), jnp.minimum(rs, seed_s)

    dt, ds = jax.lax.fori_loop(0, SWEEPS, sweep, (dt, ds))
    dt_out_ref[...] = dt.reshape(dt_out_ref.shape)
    ds_out_ref[...] = ds.reshape(ds_out_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bfs_relabel_sweeps(cap, seed_t, seed_s, dt, ds, *,
                       interpret: bool = True):
    """``SWEEPS`` joint relaxation sweeps of both wavefront planes.

    Args:
      cap: ``(4, B, H, W)`` residual neighbour capacities.
      seed_t / seed_s: ``(B, H, W)`` int32 seed planes (1 where residual
        x→t resp. N+1 where residual x→s; INF elsewhere).
      dt / ds: ``(B, H, W)`` int32 current wavefront planes.

    Returns the relaxed ``(dt, ds)``. Each batch instance is one kernel
    step of a ``(B,)`` pallas grid, so the whole batch rides one launch —
    the batch dimension ``maxflow_grid_batch`` dispatches over.
    """
    B, H, W = dt.shape
    spec2d = pl.BlockSpec((1, H, W), lambda b: (b, 0, 0))
    spec4 = pl.BlockSpec((4, 1, H, W), lambda b: (0, b, 0, 0))
    dt, ds = pl.pallas_call(
        _bfs_relabel_kernel,
        grid=(B,),
        in_specs=[spec4, spec2d, spec2d, spec2d, spec2d],
        out_specs=[spec2d, spec2d],
        out_shape=[jax.ShapeDtypeStruct((B, H, W), jnp.int32),
                   jax.ShapeDtypeStruct((B, H, W), jnp.int32)],
        interpret=interpret,
    )(cap, seed_t, seed_s, dt, ds)
    return dt, ds
