"""Fixpoint driver for the bfs_relabel kernel: the balanced backend's
global/gap relabel pass.

``bfs_relabel_heights`` has the same call shape as ``repro.core.maxflow.
grid.bfs_heights`` (shape-polymorphic over leading batch axes, jittable)
but differs in two deliberate ways:

* the relaxation sweeps run ``kernel.SWEEPS`` at a time VMEM-resident in
  the pallas kernel, so the XLA ``while_loop`` pays one HBM round trip per
  ``SWEEPS`` sweeps instead of per sweep (``max_iters`` still caps TOTAL
  sweeps, rounded up to a multiple of ``SWEEPS``);
* the labeling is BIDIRECTIONAL — unreached-from-sink nodes get the exact
  return gradient ``N + dist_to_source`` instead of the paper's flat
  ``N`` gap value, so stranded excess drains home in ``dist`` rounds
  rather than climbing by +1 relabels (see kernel.py / docs/kernels.md).

Both differences preserve the height invariant ``h(x) <= h(y) + 1`` on
residual edges (asserted after every invocation in tests/test_balanced.py)
and the fixpoint is schedule-independent, so the result is deterministic
per instance — which is what lets ``backend="balanced"`` keep the
batched == loop-of-singles bit-match contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bfs_relabel.kernel import SWEEPS, bfs_relabel_sweeps

# python int, not jnp.int32: this module is imported lazily, possibly
# inside a jit trace, where creating a jnp constant would leak a tracer
INF_H = 2 ** 30


def bfs_relabel_heights(cap, cap_src, cap_sink, h_prev, n_nodes,
                        max_iters: int, *, interpret: bool | None = None):
    """Bidirectional global/gap relabel heights (balanced backend).

    Args:
      cap: ``(4, ..., H, W)`` residual neighbour capacities.
      cap_src / cap_sink: ``(..., H, W)`` residual terminal capacities.
      h_prev: ``(..., H, W)`` int32 current heights (never decreased).
      n_nodes: the paper's N = H*W + 2 (the source's conceptual height).
      max_iters: sweep budget (0 would loop forever — callers pass the
        H*W + 2 upper bound like ``bfs_heights`` does).

    Returns ``(..., H, W)`` int32 heights: exact height-to-sink where the
    sink is residually reachable, else ``max(h_prev, N + dist_to_source)``
    where the source is, else ``max(h_prev, N)``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    *batch, H, W = h_prev.shape
    B = 1
    for s in batch:
        B *= s
    cap4 = cap.reshape(4, B, H, W)
    seed_t = jnp.where(cap_sink > 0, jnp.int32(1), INF_H).reshape(B, H, W)
    seed_s = jnp.where(cap_src > 0, n_nodes + 1, INF_H).reshape(B, H, W)

    def body(carry):
        dt, ds, _, it = carry
        nt, ns = bfs_relabel_sweeps(cap4, seed_t, seed_s, dt, ds,
                                    interpret=interpret)
        changed = jnp.any((nt != dt) | (ns != ds))
        return nt, ns, changed, it + SWEEPS

    def cond(carry):
        _, _, changed, it = carry
        return changed & (it < max_iters)

    dt, ds, _, _ = jax.lax.while_loop(
        cond, body, (seed_t, seed_s, jnp.bool_(True), jnp.int32(0)))
    dt = dt.reshape(h_prev.shape)
    ds = ds.reshape(h_prev.shape)
    return jnp.where(dt < INF_H, dt,
                     jnp.maximum(h_prev, jnp.where(ds < INF_H, ds, n_nodes)))
