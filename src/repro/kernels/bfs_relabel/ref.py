"""Pure-jnp oracle for the bfs_relabel kernel (and its combine step).

``bfs_relabel_sweeps_ref`` mirrors one kernel invocation (``SWEEPS`` joint
relaxation sweeps); ``bfs_relabel_heights_ref`` is the full bidirectional
fixpoint + combine the ops-level driver must reproduce — both are the
bit-exact references asserted in tests/test_bfs_relabel.py.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.maxflow.grid import _nbr_h

# python int, not jnp.int32 (lazy import inside a trace must not create
# jnp constants — they would leak as tracers)
INF_H = 2 ** 30


def _relax(plane, cap, seed):
    """One min-plus sweep of a wavefront plane (batch axes pass through)."""
    out = plane
    for d in range(4):
        out = jnp.minimum(
            out, jnp.where(cap[d] > 0, _nbr_h(plane, d) + 1, INF_H))
    return jnp.minimum(out, seed)


def bfs_relabel_sweeps_ref(cap, seed_t, seed_s, dt, ds, *, sweeps: int):
    """``sweeps`` joint relaxation sweeps — the kernel's per-call contract."""
    for _ in range(sweeps):
        dt, ds = _relax(dt, cap, seed_t), _relax(ds, cap, seed_s)
    return dt, ds


def bfs_relabel_heights_ref(cap, cap_src, cap_sink, h_prev, n_nodes):
    """Fixpoint + combine: the bidirectional global/gap relabel oracle.

    Runs both wavefronts to their exact fixpoints (host-bounded sweep
    count: the grid diameter is a hard cap on BFS depth), then combines:
    sink-reachable nodes take their exact height-to-sink, source-only
    nodes take ``max(h_prev, N + dist_to_source)`` (the return-flow
    gradient the paper's gap relabel flattens to N), doubly-unreached
    nodes take the paper's ``max(h_prev, N)`` (they hold no excess — see
    the flow-decomposition argument in docs/kernels.md).
    """
    import numpy as np
    seed_t = jnp.where(cap_sink > 0, jnp.int32(1), INF_H)
    seed_s = jnp.where(cap_src > 0, jnp.int32(n_nodes) + 1, INF_H)
    dt = seed_t
    ds = seed_s
    while True:  # eager oracle: iterate concrete arrays to the fixpoint
        nt, ns = _relax(dt, cap, seed_t), _relax(ds, cap, seed_s)
        if np.array_equal(np.asarray(nt), np.asarray(dt)) and \
                np.array_equal(np.asarray(ns), np.asarray(ds)):
            break
        dt, ds = nt, ns
    return jnp.where(dt < INF_H, dt,
                     jnp.maximum(h_prev,
                                 jnp.where(ds < INF_H, ds,
                                           jnp.int32(n_nodes))))
