"""Pallas TPU kernel: the per-node decision of one push-relabel Jacobi round.

The paper's push kernel (§4.6) is the hot spot of the max-flow computation:
each node scans its residual edges, finds the lowest neighbour, and either
pushes or relabels. The CUDA version keeps heights in shared memory
(Vineet & Narayanan) — the TPU analogue is VMEM tiles chosen by BlockSpec.

The kernel computes, per grid tile: the chosen target (sink / source / one of
four neighbours), the pushed amount per target plane, and the new height. The
cross-tile flow deposition (shift-adds) is pure elementwise data movement and
stays in XLA (ops.py) where it fuses with the surrounding ops; the VMEM-
resident argmin/push math — the part the paper hand-optimizes — lives here.

VMEM per step: 12 input planes + 7 output planes of BH·BW·4B.
BH=BW=256 ⇒ 19·256·256·4B ≈ 5 MB — fits VMEM with double buffering.
The halo exchange (neighbour heights) is precomputed by ops.py as 4 shifted
height planes, which on real hardware XLA lays out as cheap HBM slices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF_H = 2 ** 30  # python int: jnp scalars would be captured consts in pallas


def _grid_push_kernel(nnodes_ref, e_ref, h_ref, cap_ref, nbrh_ref, csrc_ref,
                      csink_ref, hnew_ref, delta_ref):
    # Blocks are (BH, BW) planes; in batched mode each carries a leading
    # singleton batch axis (one grid step per instance) that we squeeze here.
    bh, bw = e_ref.shape[-2:]
    e = e_ref[...].reshape(bh, bw)            # f32
    h = h_ref[...].reshape(bh, bw)            # i32
    cap = cap_ref[...].reshape(4, bh, bw)     # f32 residual neighbour caps
    nbr_h = nbrh_ref[...].reshape(4, bh, bw)  # i32 neighbour heights (halo)
    cap_src = csrc_ref[...].reshape(bh, bw)   # f32
    cap_sink = csink_ref[...].reshape(bh, bw)  # f32
    n_nodes = nnodes_ref[0]

    active = e > 0

    # candidate heights, same order as grid.jacobi_round:
    # [sink, source, UP, DOWN, LEFT, RIGHT]
    cand = jnp.concatenate([
        jnp.where(cap_sink > 0, 0, INF_H)[None],
        jnp.where(cap_src > 0, n_nodes, INF_H)[None],
        jnp.where(cap > 0, nbr_h, INF_H),
    ], axis=0)                      # (6, BH, BW)
    h_min = jnp.min(cand, axis=0)
    choice = jnp.argmin(cand, axis=0)

    do_push = active & (h > h_min)
    do_relabel = active & (h <= h_min) & (h_min < INF_H)

    cap_all = jnp.concatenate([cap_sink[None], cap_src[None], cap], axis=0)
    chosen_cap = jnp.take_along_axis(cap_all, choice[None], axis=0)[0]
    delta = jnp.where(do_push, jnp.minimum(e, chosen_cap), 0.0)

    planes = jax.lax.broadcasted_iota(jnp.int32, cand.shape, 0)
    hnew_ref[...] = jnp.where(do_relabel, h_min + 1, h).reshape(hnew_ref.shape)
    delta_ref[...] = jnp.where(planes == choice[None], delta[None],
                               0.0).reshape(delta_ref.shape)


@functools.partial(jax.jit, static_argnames=("block_h", "block_w",
                                             "interpret"))
def grid_push_decide(e, h, cap, nbr_h, cap_src, cap_sink, n_nodes,
                     *, block_h: int = 256, block_w: int = 256,
                     interpret: bool = True):
    """Per-node push/relabel decision for one Jacobi round.

    Returns (h_new, delta) where delta[p] is the flow pushed toward plane
    p ∈ [sink, source, UP, DOWN, LEFT, RIGHT].

    Accepts a leading batch axis: ``e`` may be ``(H, W)`` or ``(B, H, W)``
    (with ``cap``/``nbr_h`` ``(4, B, H, W)``). In batched mode the pallas
    grid gains a leading batch dimension — grid ``(B, H//bh, W//bw)`` — so
    every instance's tiles are independent kernel steps of ONE launch,
    amortizing the dispatch over the whole batch.
    """
    *batch, H, W = e.shape
    bh, bw = min(block_h, H), min(block_w, W)
    assert H % bh == 0 and W % bw == 0, (H, W, bh, bw)
    args = (jnp.asarray([n_nodes], jnp.int32), e, h, cap, nbr_h, cap_src,
            cap_sink)

    if not batch:
        grid = (H // bh, W // bw)
        spec2d = pl.BlockSpec((bh, bw), lambda i, j: (i, j))
        spec4 = pl.BlockSpec((4, bh, bw), lambda i, j: (0, i, j))
        spec6 = pl.BlockSpec((6, bh, bw), lambda i, j: (0, i, j))
        nnodes_spec = pl.BlockSpec((1,), lambda i, j: (0,))
        out_shape = [jax.ShapeDtypeStruct((H, W), jnp.int32),
                     jax.ShapeDtypeStruct((6, H, W), jnp.float32)]
    else:
        (B,) = batch
        grid = (B, H // bh, W // bw)
        spec2d = pl.BlockSpec((1, bh, bw), lambda b, i, j: (b, i, j))
        spec4 = pl.BlockSpec((4, 1, bh, bw), lambda b, i, j: (0, b, i, j))
        spec6 = pl.BlockSpec((6, 1, bh, bw), lambda b, i, j: (0, b, i, j))
        nnodes_spec = pl.BlockSpec((1,), lambda b, i, j: (0,))
        out_shape = [jax.ShapeDtypeStruct((B, H, W), jnp.int32),
                     jax.ShapeDtypeStruct((6, B, H, W), jnp.float32)]

    h_new, delta = pl.pallas_call(
        _grid_push_kernel,
        grid=grid,
        in_specs=[nnodes_spec, spec2d, spec2d, spec4, spec4, spec2d, spec2d],
        out_specs=[spec2d, spec6],
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    return h_new, delta
