"""Pallas TPU kernel: the per-node decision of one push-relabel Jacobi round.

The paper's push kernel (§4.6) is the hot spot of the max-flow computation:
each node scans its residual edges, finds the lowest neighbour, and either
pushes or relabels. The CUDA version keeps heights in shared memory
(Vineet & Narayanan) — the TPU analogue is VMEM tiles chosen by BlockSpec.

The kernel computes, per grid tile: the chosen target (sink / source / one of
four neighbours), the pushed amount per target plane, and the new height. The
cross-tile flow deposition (shift-adds) is pure elementwise data movement and
stays in XLA (ops.py) where it fuses with the surrounding ops; the VMEM-
resident argmin/push math — the part the paper hand-optimizes — lives here.

VMEM per step: 12 input planes + 7 output planes of BH·BW·4B.
BH=BW=256 ⇒ 19·256·256·4B ≈ 5 MB — fits VMEM with double buffering.
The halo exchange (neighbour heights) is precomputed by ops.py as 4 shifted
height planes, which on real hardware XLA lays out as cheap HBM slices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF_H = 2 ** 30  # python int: jnp scalars would be captured consts in pallas


def _decide(e, h, cap, nbr_h, cap_src, cap_sink, n_nodes):
    """The per-node decision math shared by both kernels (concrete values).

    Candidate order matches grid.jacobi_round:
    [sink, source, UP, DOWN, LEFT, RIGHT].
    """
    active = e > 0
    cand = jnp.concatenate([
        jnp.where(cap_sink > 0, 0, INF_H)[None],
        jnp.where(cap_src > 0, n_nodes, INF_H)[None],
        jnp.where(cap > 0, nbr_h, INF_H),
    ], axis=0)                      # (6, BH, BW)
    h_min = jnp.min(cand, axis=0)
    choice = jnp.argmin(cand, axis=0)

    do_push = active & (h > h_min)
    do_relabel = active & (h <= h_min) & (h_min < INF_H)

    cap_all = jnp.concatenate([cap_sink[None], cap_src[None], cap], axis=0)
    chosen_cap = jnp.take_along_axis(cap_all, choice[None], axis=0)[0]
    delta = jnp.where(do_push, jnp.minimum(e, chosen_cap), 0.0)

    planes = jax.lax.broadcasted_iota(jnp.int32, cand.shape, 0)
    h_new = jnp.where(do_relabel, h_min + 1, h)
    return h_new, jnp.where(planes == choice[None], delta[None], 0.0)


def _grid_push_kernel(nnodes_ref, e_ref, h_ref, cap_ref, nbrh_ref, csrc_ref,
                      csink_ref, hnew_ref, delta_ref):
    # Blocks are (BH, BW) planes; in batched mode each carries a leading
    # singleton batch axis (one grid step per instance) that we squeeze here.
    bh, bw = e_ref.shape[-2:]
    e = e_ref[...].reshape(bh, bw)            # f32
    h = h_ref[...].reshape(bh, bw)            # i32
    cap = cap_ref[...].reshape(4, bh, bw)     # f32 residual neighbour caps
    nbr_h = nbrh_ref[...].reshape(4, bh, bw)  # i32 neighbour heights (halo)
    cap_src = csrc_ref[...].reshape(bh, bw)   # f32
    cap_sink = csink_ref[...].reshape(bh, bw)  # f32
    n_nodes = nnodes_ref[0]

    h_new, delta = _decide(e, h, cap, nbr_h, cap_src, cap_sink, n_nodes)
    hnew_ref[...] = h_new.reshape(hnew_ref.shape)
    delta_ref[...] = delta.reshape(delta_ref.shape)


def _grid_push_sched_kernel(sched_ref, nact_ref, nnodes_ref, e_ref, h_ref,
                            cap_ref, nbrh_ref, csrc_ref, csink_ref,
                            hnew_ref, delta_ref):
    """Active-tile-scheduled decision step (workload-balanced backend).

    Grid is ``(B, T)`` over SCHEDULE POSITIONS, not tile coordinates: the
    scalar-prefetched ``sched[b]`` is a permutation of instance ``b``'s
    tile ids with the active tiles compacted to the front, and this
    program's blocks are tile ``sched[b, i]`` (index maps below). Schedule
    positions past ``nact[b]`` carry tiles with NO active vertex — for
    them one Jacobi round is the identity (no node pushes or relabels), so
    the kernel skips the whole candidate/argmin/push stage and writes the
    identity outputs directly. The permutation covers every tile exactly
    once, so every output block is written exactly once.
    """
    b = pl.program_id(0)
    i = pl.program_id(1)
    bh, bw = e_ref.shape[-2:]

    @pl.when(i < nact_ref[b])
    def _active_tile():
        e = e_ref[...].reshape(bh, bw)
        h = h_ref[...].reshape(bh, bw)
        cap = cap_ref[...].reshape(4, bh, bw)
        nbr_h = nbrh_ref[...].reshape(4, bh, bw)
        cap_src = csrc_ref[...].reshape(bh, bw)
        cap_sink = csink_ref[...].reshape(bh, bw)
        h_new, delta = _decide(e, h, cap, nbr_h, cap_src, cap_sink,
                               nnodes_ref[0])
        hnew_ref[...] = h_new.reshape(hnew_ref.shape)
        delta_ref[...] = delta.reshape(delta_ref.shape)

    @pl.when(i >= nact_ref[b])
    def _inactive_tile():  # identity: no active node -> no push, no relabel
        hnew_ref[...] = h_ref[...]
        delta_ref[...] = jnp.zeros_like(delta_ref)


@functools.partial(jax.jit, static_argnames=("block_h", "block_w",
                                             "interpret"))
def grid_push_decide(e, h, cap, nbr_h, cap_src, cap_sink, n_nodes,
                     *, block_h: int = 256, block_w: int = 256,
                     interpret: bool = True):
    """Per-node push/relabel decision for one Jacobi round.

    Returns (h_new, delta) where delta[p] is the flow pushed toward plane
    p ∈ [sink, source, UP, DOWN, LEFT, RIGHT].

    Accepts a leading batch axis: ``e`` may be ``(H, W)`` or ``(B, H, W)``
    (with ``cap``/``nbr_h`` ``(4, B, H, W)``). In batched mode the pallas
    grid gains a leading batch dimension — grid ``(B, H//bh, W//bw)`` — so
    every instance's tiles are independent kernel steps of ONE launch,
    amortizing the dispatch over the whole batch.
    """
    *batch, H, W = e.shape
    bh, bw = min(block_h, H), min(block_w, W)
    assert H % bh == 0 and W % bw == 0, (H, W, bh, bw)
    args = (jnp.asarray([n_nodes], jnp.int32), e, h, cap, nbr_h, cap_src,
            cap_sink)

    if not batch:
        grid = (H // bh, W // bw)
        spec2d = pl.BlockSpec((bh, bw), lambda i, j: (i, j))
        spec4 = pl.BlockSpec((4, bh, bw), lambda i, j: (0, i, j))
        spec6 = pl.BlockSpec((6, bh, bw), lambda i, j: (0, i, j))
        nnodes_spec = pl.BlockSpec((1,), lambda i, j: (0,))
        out_shape = [jax.ShapeDtypeStruct((H, W), jnp.int32),
                     jax.ShapeDtypeStruct((6, H, W), jnp.float32)]
    else:
        (B,) = batch
        grid = (B, H // bh, W // bw)
        spec2d = pl.BlockSpec((1, bh, bw), lambda b, i, j: (b, i, j))
        spec4 = pl.BlockSpec((4, 1, bh, bw), lambda b, i, j: (0, b, i, j))
        spec6 = pl.BlockSpec((6, 1, bh, bw), lambda b, i, j: (0, b, i, j))
        nnodes_spec = pl.BlockSpec((1,), lambda b, i, j: (0,))
        out_shape = [jax.ShapeDtypeStruct((B, H, W), jnp.int32),
                     jax.ShapeDtypeStruct((6, B, H, W), jnp.float32)]

    h_new, delta = pl.pallas_call(
        _grid_push_kernel,
        grid=grid,
        in_specs=[nnodes_spec, spec2d, spec2d, spec4, spec4, spec2d, spec2d],
        out_specs=[spec2d, spec6],
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    return h_new, delta


@functools.partial(jax.jit, static_argnames=("block_h", "block_w",
                                             "interpret"))
def grid_push_decide_sched(e, h, cap, nbr_h, cap_src, cap_sink, sched,
                           n_active, n_nodes, *, block_h: int = 64,
                           block_w: int = 64, interpret: bool = True):
    """Active-tile-scheduled push/relabel decision (balanced backend).

    Same outputs as ``grid_push_decide`` — ``(h_new, delta)`` with
    ``delta[p]`` the flow pushed toward plane p ∈ [sink, source, UP, DOWN,
    LEFT, RIGHT] — but the pallas grid runs over a COMPACTED TILE SCHEDULE
    instead of fixed (i, j) tiling:

    Args:
      e / h / cap_src / cap_sink: ``(B, H, W)`` state planes.
      cap / nbr_h: ``(4, B, H, W)``.
      sched: ``(B, T)`` int32 — per instance, a PERMUTATION of the tile
        ids ``0..T-1`` (``T = (H//block_h) * (W//block_w)``, row-major)
        with every tile containing an active vertex compacted to the
        front (``repro.kernels.grid_push.ops.tile_schedule``).
      n_active: ``(B,)`` int32 — how many leading schedule entries are
        active; programs past it take the identity fast path.
      n_nodes: scalar int32 (the paper's N).

    ``sched`` and ``n_active`` ride scalar prefetch
    (``pltpu.PrefetchScalarGridSpec``) so the BLOCK INDEX MAPS themselves
    gather the scheduled tile — the kernel's memory traffic follows the
    schedule, which is what makes the dispatch workload-balanced rather
    than grid-shaped. Inactive tiles are provably identity under one
    Jacobi round, so the result is bit-identical to ``grid_push_decide``
    on the full grid (asserted in tests/test_balanced.py).
    """
    B, H, W = e.shape
    bh, bw = min(block_h, H), min(block_w, W)
    if H % bh:
        bh = H
    if W % bw:
        bw = W
    ntw = W // bw
    T = (H // bh) * ntw
    assert sched.shape == (B, T), (sched.shape, B, T)

    def tile2d(b, i, sched, nact, nn):
        t = sched[b, i]
        return (b, t // ntw, t % ntw)

    def tile4(b, i, sched, nact, nn):
        t = sched[b, i]
        return (0, b, t // ntw, t % ntw)

    def tile6(b, i, sched, nact, nn):
        t = sched[b, i]
        return (0, b, t // ntw, t % ntw)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,   # sched, n_active, n_nodes
        grid=(B, T),
        in_specs=[pl.BlockSpec((1, bh, bw), tile2d),
                  pl.BlockSpec((1, bh, bw), tile2d),
                  pl.BlockSpec((4, 1, bh, bw), tile4),
                  pl.BlockSpec((4, 1, bh, bw), tile4),
                  pl.BlockSpec((1, bh, bw), tile2d),
                  pl.BlockSpec((1, bh, bw), tile2d)],
        out_specs=[pl.BlockSpec((1, bh, bw), tile2d),
                   pl.BlockSpec((6, 1, bh, bw), tile6)],
    )
    h_new, delta = pl.pallas_call(
        _grid_push_sched_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, H, W), jnp.int32),
                   jax.ShapeDtypeStruct((6, B, H, W), jnp.float32)],
        interpret=interpret,
    )(sched.astype(jnp.int32), n_active.astype(jnp.int32),
      jnp.asarray([n_nodes], jnp.int32), e, h, cap, nbr_h, cap_src,
      cap_sink)
    return h_new, delta
