"""Pure-jnp oracle for the grid_push kernel (mirrors grid.jacobi_round)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF_H = jnp.int32(2 ** 30)


def grid_push_decide_ref(e, h, cap, nbr_h, cap_src, cap_sink, n_nodes):
    active = e > 0
    cand = jnp.concatenate([
        jnp.where(cap_sink > 0, 0, INF_H)[None],
        jnp.where(cap_src > 0, jnp.int32(n_nodes), INF_H)[None],
        jnp.where(cap > 0, nbr_h, INF_H),
    ], axis=0)
    h_min = jnp.min(cand, axis=0)
    choice = jnp.argmin(cand, axis=0)
    do_push = active & (h > h_min)
    do_relabel = active & (h <= h_min) & (h_min < INF_H)

    cap_all = jnp.concatenate([cap_sink[None], cap_src[None], cap], axis=0)
    chosen_cap = jnp.take_along_axis(cap_all, choice[None], axis=0)[0]
    delta = jnp.where(do_push, jnp.minimum(e, chosen_cap), 0.0)
    planes = jax.lax.broadcasted_iota(jnp.int32, cand.shape, 0)
    h_new = jnp.where(do_relabel, h_min + 1, h)
    return h_new, jnp.where(planes == choice[None], delta[None], 0.0)
