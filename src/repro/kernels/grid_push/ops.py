"""Jit'd wrapper: a full Jacobi round with the Pallas decision kernel.

Produces bit-identical state transitions to ``repro.core.maxflow.grid.
jacobi_round`` (asserted in tests); the wrapper adds the halo gather before
the kernel and the shift-add flow deposition after it. Like the XLA round it
is shape-polymorphic over a leading batch axis (``e``: ``(..., H, W)``,
``cap``: ``(4, ..., H, W)``) — the kernel grid then gains a batch dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.maxflow.grid import (GridFlowState, _OPP, _gsum, _move,
                                     _nbr_h)
from repro.kernels.grid_push.kernel import grid_push_decide
from repro.kernels.grid_push.ref import grid_push_decide_ref


def jacobi_round_pallas(state: GridFlowState, n_nodes,
                        *, block_h: int = 256, block_w: int = 256,
                        interpret: bool | None = None) -> GridFlowState:
    e, h, cap, cap_src, cap_sink, sink_flow, src_flow = state
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    nbr_h = jnp.stack([_nbr_h(h, d) for d in range(4)], axis=0)
    h_new, delta = grid_push_decide(
        e, h, cap, nbr_h, cap_src, cap_sink, n_nodes,
        block_h=block_h, block_w=block_w, interpret=interpret)

    d_sink, d_src = delta[0], delta[1]
    d_nbr = [delta[2 + d] for d in range(4)]
    out = d_sink + d_src + sum(d_nbr)
    inflow = sum(_move(d_nbr[d], d) for d in range(4))
    cap_new = jnp.stack(
        [cap[d] - d_nbr[d] + _move(d_nbr[_OPP[d]], _OPP[d]) for d in range(4)],
        0)
    return GridFlowState(
        e=e - out + inflow, h=h_new, cap=cap_new,
        cap_src=cap_src - d_src, cap_sink=cap_sink - d_sink,
        sink_flow=sink_flow + _gsum(d_sink),
        src_flow=src_flow + _gsum(d_src),
    )
