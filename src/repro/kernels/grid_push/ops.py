"""Jit'd wrappers: full Jacobi rounds with the Pallas decision kernels.

``jacobi_round_pallas`` produces bit-identical state transitions to
``repro.core.maxflow.grid.jacobi_round`` (asserted in tests); the wrapper
adds the halo gather before the kernel and the shift-add flow deposition
after it. Like the XLA round it is shape-polymorphic over a leading batch
axis (``e``: ``(..., H, W)``, ``cap``: ``(4, ..., H, W)``) — the kernel
grid then gains a batch dimension.

``jacobi_round_scheduled`` is the workload-balanced variant: it builds a
per-instance ACTIVE-TILE SCHEDULE (tiles holding at least one node with
excess, compacted to the front of a tile-id permutation) and dispatches
the decision kernel over schedule positions instead of the fixed grid.
A tile with no active node is an exact no-op under one Jacobi round, so
the transition is still bit-identical to ``jacobi_round`` — the schedule
only changes which blocks do real work. It additionally returns the
per-instance RETIRED flow (excess delivered to a terminal this round),
which the balanced backend's stall detector (``repro.core.maxflow.grid``)
feeds into its relabel-trigger EWMA — neighbour-to-neighbour moves are
excluded because height-plateau ping-pong would otherwise read as
progress.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.maxflow.grid import (GridFlowState, _OPP, _gsum, _move,
                                     _nbr_h)
from repro.kernels.grid_push.kernel import (grid_push_decide,
                                            grid_push_decide_sched)
from repro.kernels.grid_push.ref import grid_push_decide_ref


def _deposit(state: GridFlowState, h_new, delta) -> GridFlowState:
    """Shift-add flow deposition shared by both round wrappers."""
    d_sink, d_src = delta[0], delta[1]
    d_nbr = [delta[2 + d] for d in range(4)]
    out = d_sink + d_src + sum(d_nbr)
    inflow = sum(_move(d_nbr[d], d) for d in range(4))
    cap_new = jnp.stack(
        [state.cap[d] - d_nbr[d] + _move(d_nbr[_OPP[d]], _OPP[d])
         for d in range(4)], 0)
    return state._replace(
        e=state.e - out + inflow, h=h_new, cap=cap_new,
        cap_src=state.cap_src - d_src, cap_sink=state.cap_sink - d_sink,
        sink_flow=state.sink_flow + _gsum(d_sink),
        src_flow=state.src_flow + _gsum(d_src),
    )


def jacobi_round_pallas(state: GridFlowState, n_nodes,
                        *, block_h: int = 256, block_w: int = 256,
                        interpret: bool | None = None) -> GridFlowState:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    nbr_h = jnp.stack([_nbr_h(state.h, d) for d in range(4)], axis=0)
    h_new, delta = grid_push_decide(
        state.e, state.h, state.cap, nbr_h, state.cap_src, state.cap_sink,
        n_nodes, block_h=block_h, block_w=block_w, interpret=interpret)
    return _deposit(state, h_new, delta)


def tile_schedule(active, block_h: int, block_w: int):
    """Compacted tile schedule from a per-node activity mask.

    Args:
      active: ``(B, H, W)`` bool — which nodes hold excess this round.
      block_h / block_w: the kernel tile shape (must divide H, W).

    Returns ``(sched, n_active)``: ``sched`` is ``(B, T)`` int32, per
    instance a permutation of the row-major tile ids with every tile
    containing an active node moved to the front (stable, so active tiles
    keep tile-id order — the schedule is a pure function of the mask,
    which preserves the per-instance determinism contract); ``n_active``
    is ``(B,)`` int32.
    """
    B, H, W = active.shape
    nth, ntw = H // block_h, W // block_w
    tile_act = active.reshape(B, nth, block_h, ntw, block_w).any(axis=(2, 4))
    tile_act = tile_act.reshape(B, nth * ntw)
    sched = jnp.argsort(~tile_act, axis=1, stable=True).astype(jnp.int32)
    return sched, jnp.sum(tile_act, axis=1).astype(jnp.int32)


def jacobi_round_scheduled(state: GridFlowState, n_nodes,
                           *, block_h: int = 64, block_w: int = 64,
                           interpret: bool | None = None):
    """One Jacobi round dispatched over active tiles only.

    Bit-identical state transition to ``jacobi_round`` /
    ``jacobi_round_pallas`` (inactive tiles are no-ops either way); the
    pallas grid just stops visiting them first. Returns
    ``(new_state, retired)`` where ``retired`` is the per-instance flow
    delivered to the sink or returned to the source this round — the
    balanced backend's stall signal (see module docstring).
    Shape-polymorphic over leading batch axes.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    *batch, H, W = state.e.shape
    bh, bw = min(block_h, H), min(block_w, W)
    if H % bh:
        bh = H
    if W % bw:
        bw = W
    B = 1
    for s in batch:
        B *= s

    e = state.e.reshape(B, H, W)
    h = state.h.reshape(B, H, W)
    cap = state.cap.reshape(4, B, H, W)
    cap_src = state.cap_src.reshape(B, H, W)
    cap_sink = state.cap_sink.reshape(B, H, W)
    nbr_h = jnp.stack([_nbr_h(h, d) for d in range(4)], axis=0)
    sched, n_active = tile_schedule(e > 0, bh, bw)

    h_new, delta = grid_push_decide_sched(
        e, h, cap, nbr_h, cap_src, cap_sink, sched, n_active, n_nodes,
        block_h=bh, block_w=bw, interpret=interpret)

    h_new = h_new.reshape(state.h.shape)
    delta = delta.reshape((6,) + state.e.shape)
    retired = _gsum(delta[0] + delta[1])
    return _deposit(state, h_new, delta), retired
