"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = dh ** -0.5 if scale is None else scale
    kk = jnp.repeat(k, G, 2).astype(jnp.float32)
    vv = jnp.repeat(v, G, 2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk) * scale
    if causal:
        Sk = k.shape[1]
        m = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv).astype(q.dtype)
