"""Jit'd wrapper (auto-interpret off-TPU) for the flash fwd kernel."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_attention_ref  # noqa


def flash_attention_op(q, k, v, *, causal=True, scale=None,
                       block_q=256, block_k=512):
    interpret = jax.default_backend() != "tpu"
    return flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
