"""Pallas TPU flash-attention forward kernel (beyond-paper optimization).

The dry-run roofline shows every attention-heavy cell is MEMORY-bound on
score/prob traffic: the jnp flash implementation materializes the
(B, H, Sq, C) score block in HBM once per key chunk (f32), ~10 TB/device
per step on smollm train_4k. This kernel keeps the whole (q-block × k-block)
working set in VMEM — HBM traffic drops to the q/k/v/o tensors themselves
(napkin math in EXPERIMENTS.md §Perf: ~100x less attention traffic).

Grid: (B·H, Sq/BQ, Sk/BK), k-block innermost so the accumulator tile stays
resident. BlockSpec tiling (BQ=256, BK=512, dh<=256):
  q tile 256·dh·4B ≈ 256 KB, k/v tiles 512·dh·4B ≈ 512 KB each,
  s/p tile 256·512·4B = 512 KB, acc 256·dv·4B + stats ≈ 300 KB
  => < 2.5 MB, double-buffered well under the 16 MB VMEM budget; MXU dims
  (256, 512) × (512, dh) are 128-aligned.

GQA is handled by the index_map: the kv BlockSpec maps head h to kv-head
h // (H // KV), so no repeated K/V ever exists in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      scale: float, causal: bool, block_q: int,
                      block_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # (BQ, dh)
    k = k_ref[0]                                   # (BK, dh)
    v = v_ref[0]                                   # (BK, dv)

    run = True
    if causal:
        # skip fully-masked blocks (upper triangle)
        run = (kj * block_k) <= (qi * block_q + block_q - 1)

    @pl.when(run if causal else True)
    def _compute():
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)
        if causal:
            pos_q = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            pos_k = kj * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(pos_q >= pos_k, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        scale: float | None = None, block_q: int = 256,
                        block_k: int = 512, interpret: bool = True):
    """q: (B, Sq, H, dh); k/v: (B, Sk, KV, dh/dv). Returns (B, Sq, H, dv).

    VMEM tiling per the module docstring; interpret=True validates on CPU.
    """
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    scale = dh ** -0.5 if scale is None else scale
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0

    # (B, S, H, d) -> (B*H, S, d) so one grid row owns one (batch, head)
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, dh)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, dv)

    grid = (B * H, Sq // bq, Sk // bk)

    def q_map(bh, qi, kj):
        return (bh, qi, 0)

    def kv_map(bh, qi, kj):
        # GQA: head bh -> kv row (batch * KV + head // G)
        return ((bh // H) * KV + (bh % H) // G, kj, 0)

    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        functools.partial(_flash_fwd_kernel, scale=scale, causal=causal,
                          block_q=bq, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), q_map),
            pl.BlockSpec((1, bk, dh), kv_map),
            pl.BlockSpec((1, bk, dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),    # acc tile
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denom
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, dv).transpose(0, 2, 1, 3)
