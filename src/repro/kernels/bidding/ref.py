"""Pure-jnp oracle for the bidding kernel."""
from __future__ import annotations

import jax.numpy as jnp

INF = jnp.int32(2 ** 30)


def bidding_ref(c, p_y, mask):
    adj = jnp.where(mask, INF, c - p_y[None, :])
    min1 = jnp.min(adj, axis=1)
    arg1 = jnp.argmin(adj, axis=1)
    n = adj.shape[1]
    adj2 = jnp.where(jnp.arange(n)[None, :] == arg1[:, None], INF, adj)
    min2 = jnp.min(adj2, axis=1)
    return min1, arg1.astype(jnp.int32), min2
