"""Pallas TPU kernel: per-row top-2 minimum of masked part-reduced costs.

This is the compute hot-spot of the paper's Refine (Algorithm 5.4 lines 6-10:
"select the residual edge with the lowest part-reduced cost") and of the
auction bid (top-2). On the GPU the paper scans adjacency lists per thread;
on TPU we tile the dense complete-bipartite cost matrix through VMEM and keep
a running (min1, arg1, min2) accumulator per row block.

Tiling: grid = (n_rows/BR, n_cols/BC); the column dimension is innermost so
each row-block's accumulator stays resident in its output VMEM block across
the whole column sweep (flash-attention-style streaming reduction). VMEM
working set per grid step = BR·BC·4B (costs) + BR·BC (mask) + BC·4B (prices)
+ 3·BR·4B (accumulators) — BR=256, BC=512 ⇒ ~0.7 MB ≪ 16 MB VMEM, leaving
room for double buffering of the streamed cost tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = 2 ** 30  # python int: jnp scalars would be captured consts in pallas


def _bidding_kernel(c_ref, p_ref, m_ref, min1_ref, arg1_ref, min2_ref, *,
                    block_cols: int):
    j = pl.program_id(1)

    c = c_ref[...]                       # (BR, BC) int32 costs
    p = p_ref[...]                       # (1, BC) int32 prices
    m = m_ref[...]                       # (BR, BC) bool: True = not residual
    adj = jnp.where(m, INF, c - p)       # part-reduced cost c'_p = c - p(y)

    # local top-2 along the tile's columns
    l_min1 = jnp.min(adj, axis=1, keepdims=True)                  # (BR, 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, adj.shape, 1)
    l_arg1 = jnp.min(jnp.where(adj == l_min1, cols, INF), axis=1,
                     keepdims=True)                               # first argmin
    adj2 = jnp.where(cols == l_arg1, INF, adj)
    l_min2 = jnp.min(adj2, axis=1, keepdims=True)
    l_arg1 = l_arg1 + j * block_cols                              # global col

    @pl.when(j == 0)
    def _init():
        min1_ref[...] = l_min1
        arg1_ref[...] = l_arg1
        min2_ref[...] = l_min2

    @pl.when(j > 0)
    def _merge():
        r_min1, r_arg1, r_min2 = min1_ref[...], arg1_ref[...], min2_ref[...]
        take_new = l_min1 < r_min1
        n_min1 = jnp.where(take_new, l_min1, r_min1)
        n_arg1 = jnp.where(take_new, l_arg1, r_arg1)
        # second-best among {loser of the min1 duel, both min2 candidates}
        loser = jnp.where(take_new, r_min1, l_min1)
        n_min2 = jnp.minimum(loser, jnp.minimum(l_min2, r_min2))
        min1_ref[...] = n_min1
        arg1_ref[...] = n_arg1
        min2_ref[...] = n_min2


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols",
                                             "interpret"))
def bidding(c: jax.Array, p_y: jax.Array, mask: jax.Array,
            *, block_rows: int = 256, block_cols: int = 512,
            interpret: bool = True):
    """Row-wise (min1, arg1, min2) of ``where(mask, INF, c - p_y)``.

    interpret=True executes the kernel body on CPU (validation mode); on a
    real TPU pass interpret=False.
    """
    n_r, n_c = c.shape
    br, bc = min(block_rows, n_r), min(block_cols, n_c)
    assert n_r % br == 0 and n_c % bc == 0, (n_r, n_c, br, bc)
    grid = (n_r // br, n_c // bc)

    out_shape = [jax.ShapeDtypeStruct((n_r, 1), jnp.int32)] * 3
    out_spec = pl.BlockSpec((br, 1), lambda i, j: (i, 0))
    min1, arg1, min2 = pl.pallas_call(
        functools.partial(_bidding_kernel, block_cols=bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(c, p_y.reshape(1, -1), mask)
    return min1[:, 0], arg1[:, 0], min2[:, 0]
