"""Jit'd public wrapper for the bidding kernel (auto interpret on CPU)."""
from __future__ import annotations

import jax

from repro.kernels.bidding.kernel import bidding
from repro.kernels.bidding.ref import bidding_ref  # noqa: F401  (oracle)


def bidding_op(c, p_y, mask, *, block_rows: int = 256, block_cols: int = 512):
    interpret = jax.default_backend() != "tpu"
    return bidding(c, p_y, mask, block_rows=block_rows,
                   block_cols=block_cols, interpret=interpret)
