"""Span tracing: the request-lifecycle half of ``repro.obs``.

A ``Tracer`` records SPANS — named time intervals with attributes — from
any number of threads at once and exports them as a plain event list or
as Chrome-trace JSON (the format Perfetto / ``chrome://tracing`` load
directly).  The serving stack emits one span chain per ticket::

    submit -> queue-wait -> bucket/pad -> device-solve -> resolve

plus ``refill-admission`` spans at continuous-batching cycle boundaries,
every span tagged with ``ticket`` / ``kind`` / bucket-shape attributes so
a trace reconstructs each request's full lifecycle (tests/test_obs.py).

Design constraints (the ISSUE's "lock-free in the hot path"):

* RECORDING takes no lock: finished spans are appended to a
  ``collections.deque`` (append is atomic under the GIL) and span nesting
  lives in per-thread stacks (``threading.local``), so submit paths, the
  scheduler thread, and lane threads never contend.
* DISABLED tracing costs one ``None`` check: instrumented code guards
  every span with ``if tracer is not None`` and the ambient tracer is a
  ``contextvars.ContextVar`` (``current_tracer()``), so the untraced hot
  path does no clock reads, no allocation, no dict building.
* Timestamps come from ``time.monotonic()`` — the same clock the
  scheduler's deadlines and latency metrics use, so retroactive spans
  (``record``) built from scheduler timestamps land on one axis.

Nothing here imports jax: the module stays importable (and the tracer
testable) without touching device state.  The device-timeline hook
(``step_annotation``) imports ``jax.profiler`` lazily and only when
annotating.
"""
from __future__ import annotations

import collections
import contextlib
import contextvars
import itertools
import json
import os
import threading
import time
from typing import Any, NamedTuple


class Span(NamedTuple):
    """One finished span: a named ``[t0, t1]`` interval with attributes.

    ``tid`` is the recording thread's ident; ``parent_id`` is the span id
    of the span that was OPEN on that thread when this one was recorded
    (``None`` at top level) — nesting is per thread, matching how the
    scheduler's threads each own a stage of a request's lifecycle.
    """

    name: str
    t0: float                  # time.monotonic() seconds
    t1: float
    tid: int
    attrs: dict
    span_id: int
    parent_id: int | None


class Tracer:
    """Thread-safe span recorder; export via ``spans()`` / ``to_chrome()``.

    Use ``span(name, **attrs)`` as a context manager for spans that open
    and close on one thread (nesting is tracked automatically), and
    ``record(name, t0, t1, **attrs)`` for RETROACTIVE spans whose
    endpoints were measured elsewhere — e.g. queue-wait, whose start is
    the submit timestamp taken on the caller's thread and whose end is
    the scheduler thread's pop.  ``instant(name, **attrs)`` records a
    zero-length mark.
    """

    def __init__(self):
        self._events: collections.deque[Span] = collections.deque()
        self._ids = itertools.count(1)
        self._local = threading.local()

    # ---- recording (lock-free) ------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Record a span around the ``with`` body (per-thread nesting)."""
        stack = self._stack()
        parent = stack[-1] if stack else None
        sid = next(self._ids)
        stack.append(sid)
        t0 = time.monotonic()
        try:
            yield
        finally:
            t1 = time.monotonic()
            stack.pop()
            self._events.append(Span(name, t0, t1, threading.get_ident(),
                                     attrs, sid, parent))

    def record(self, name: str, t0: float, t1: float, **attrs) -> int:
        """Record a retroactive span from externally-measured endpoints.

        The parent is whatever span is open on the CALLING thread (usually
        none — cross-thread stages are stitched by their shared ``ticket``
        attribute, not by parent ids).  Returns the span id.
        """
        stack = self._stack()
        sid = next(self._ids)
        self._events.append(Span(name, t0, t1, threading.get_ident(), attrs,
                                 sid, stack[-1] if stack else None))
        return sid

    def instant(self, name: str, **attrs) -> int:
        """Record a zero-length mark at the current time."""
        now = time.monotonic()
        return self.record(name, now, now, **attrs)

    # ---- export ----------------------------------------------------------

    def spans(self) -> list[Span]:
        """Finished spans in completion order (a plain event list)."""
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()

    def to_chrome(self) -> dict:
        """The trace as a Chrome-trace / Perfetto JSON object.

        Every span becomes one ``"X"`` (complete) event; ``ts``/``dur``
        are microseconds on the ``time.monotonic`` axis, ``args`` carries
        the span attributes plus ``span_id``/``parent_id``.
        """
        pid = os.getpid()
        events = [{
            "name": s.name, "ph": "X", "pid": pid, "tid": s.tid,
            "ts": s.t0 * 1e6, "dur": max(s.t1 - s.t0, 0.0) * 1e6,
            "args": {**s.attrs, "span_id": s.span_id,
                     "parent_id": s.parent_id},
        } for s in self._events]
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path) -> None:
        """Write the Chrome-trace JSON to ``path`` (open it in Perfetto)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


def load_trace(path) -> list[dict]:
    """Load a saved trace; returns its ``traceEvents`` list.

    Accepts both the object form ``Tracer.save`` writes and the bare
    event-array form of the Chrome-trace spec.
    """
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        raise ValueError(f"{path} is not a Chrome-trace file")
    return events


# ---- ambient tracer ------------------------------------------------------

_tracer_var: contextvars.ContextVar[Tracer | None] = \
    contextvars.ContextVar("repro_obs_tracer", default=None)


def current_tracer() -> Tracer | None:
    """The ambient tracer installed by ``use_tracer``, or ``None``.

    A ``ContextVar``, so it does NOT cross thread starts: long-lived
    engines capture it ONCE at construction (``tracer=`` falls back to
    this) and hand it to their worker threads explicitly.
    """
    return _tracer_var.get()


@contextlib.contextmanager
def use_tracer(tracer: Tracer | None):
    """Install ``tracer`` as the ambient tracer for the ``with`` body."""
    token = _tracer_var.set(tracer)
    try:
        yield tracer
    finally:
        _tracer_var.reset(token)


# ---- device-timeline hook ------------------------------------------------

@contextlib.contextmanager
def step_annotation(name: str, **attrs: Any):
    """Annotate the jax-profiler device timeline for the ``with`` body.

    When a ``jax.profiler.trace`` capture is running, the annotation shows
    up on the device timeline under ``name`` — lining device work up with
    the host spans this module records.  A no-op (and jax-import-free)
    when jax is unavailable; instrumented code additionally gates it on an
    active tracer so the untraced hot path never touches the profiler.
    """
    try:
        from jax.profiler import TraceAnnotation
    except Exception:                                  # pragma: no cover
        yield
        return
    with TraceAnnotation(name, **attrs):
        yield
