"""Prometheus-style text exposition of ``SchedulerMetrics``.

``prometheus_text`` renders a ``SchedulerMetrics`` (or one of its
``snapshot()`` dicts) in the Prometheus text exposition format —
``# HELP`` / ``# TYPE`` headers plus one sample per value, labels for
per-kind / per-trigger / per-driver breakdowns.  Serve it from any HTTP
handler (docs/observability.md has the scrape snippet).

COMPLETENESS IS ENFORCED: every top-level snapshot key must have a
registered renderer (``_RENDERERS``), and a key without one raises — so a
future PR that adds a metric to ``SchedulerMetrics.snapshot()`` cannot
silently ship an exposition that omits it (the acceptance contract of
tests/test_obs.py).  ``None`` values (EWMAs before their first
observation, percentiles of an empty window) keep their family header but
emit no sample, which is how Prometheus represents "no data yet".
"""
from __future__ import annotations

from typing import Any

_PREFIX = "repro"


def _escape(v: Any) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace(
        "\n", r"\n")


class _Writer:
    def __init__(self):
        self.lines: list[str] = []

    def family(self, name: str, kind: str, help_: str) -> str:
        name = f"{_PREFIX}_{name}"
        self.lines.append(f"# HELP {name} {help_}")
        self.lines.append(f"# TYPE {name} {kind}")
        return name

    def sample(self, name: str, value, labels: dict | None = None) -> None:
        if value is None:
            return
        lbl = ""
        if labels:
            inner = ",".join(f'{k}="{_escape(v)}"'
                             for k, v in labels.items())
            lbl = "{" + inner + "}"
        self.lines.append(f"{name}{lbl} {float(value):g}")


def _r_queue_depth(w: _Writer, v) -> None:
    w.sample(w.family("queue_depth", "gauge",
                      "Requests queued but not yet dispatched."), v)


def _r_tickets(w: _Writer, v: dict) -> None:
    n = w.family("tickets_total", "counter",
                 "Tickets by terminal status (submitted/completed/"
                 "failed/cancelled).")
    for status, count in sorted(v.items()):
        w.sample(n, count, {"status": status})


def _r_flushes(w: _Writer, v: dict) -> None:
    n = w.family("flushes_total", "counter",
                 "Batch flushes by trigger (size/deadline/manual/drain).")
    for trigger, count in sorted(v.items()):
        w.sample(n, count, {"trigger": trigger})


def _r_dispatches(w: _Writer, v: dict) -> None:
    n = w.family("dispatches_total", "counter",
                 "Bucket dispatches by solver kind and loop driver.")
    for key, count in sorted(v.items()):
        kind, _, driver = key.partition(":")
        w.sample(n, count, {"kind": kind, "driver": driver})


def _r_latency(w: _Writer, v: dict) -> None:
    n = w.family("ticket_latency_ms", "gauge",
                 "Ticket latency percentiles (submit -> resolution) over "
                 "the recent window.")
    for key, val in sorted(v.items()):
        q = float(key.lstrip("p")) / 100.0
        w.sample(n, val, {"quantile": f"{q:g}"})


def _r_latency_samples(w: _Writer, v) -> None:
    w.sample(w.family("ticket_latency_samples", "gauge",
                      "Tickets currently in the latency window."), v)


def _r_compact_cycles(w: _Writer, v) -> None:
    w.sample(w.family("compact_cycles_total", "counter",
                      "Host cycles executed by the compacted driver."), v)


def _r_compact_live_mean(w: _Writer, v) -> None:
    w.sample(w.family("compact_live_mean", "gauge",
                      "Mean live instances per compacted cycle."), v)


def _r_refill(w: _Writer, v: dict) -> None:
    n = w.family("refill_sessions_total", "counter",
                 "Continuous-batching sessions opened, by kind.")
    for kind, count in sorted(v["sessions"].items()):
        w.sample(n, count, {"kind": kind})
    n = w.family("refill_admitted_total", "counter",
                 "Requests admitted mid-solve into refill sessions, "
                 "by kind.")
    for kind, count in sorted(v["admitted"].items()):
        w.sample(n, count, {"kind": kind})
    n = w.family("refill_slot_occupancy_ewma", "gauge",
                 "EWMA of per-cycle slot occupancy (live/capacity) of "
                 "refill sessions, by kind.")
    for kind, val in sorted(v["slot_occupancy_ewma"].items()):
        w.sample(n, val, {"kind": kind})
    w.sample(w.family("refill_utilization", "gauge",
                      "Steady-state mean live/capacity across all refill "
                      "cycles."), v["utilization"])


def _r_warm(w: _Writer, v: dict) -> None:
    n = w.family("warm_cache_lookups_total", "counter",
                 "Solution-cache lookups on the warm-start path, by "
                 "result (hit/miss).")
    w.sample(n, v["cache_hits"], {"result": "hit"})
    w.sample(n, v["cache_misses"], {"result": "miss"})
    w.sample(w.family("warm_cache_hit_rate", "gauge",
                      "Fraction of solution-cache lookups that hit."),
             v["cache_hit_rate"])
    n = w.family("warm_solves_total", "counter",
                 "Solver instances dispatched, by init mode (warm/cold).")
    w.sample(n, v["warm_solves"], {"init": "warm"})
    w.sample(n, v["cold_solves"], {"init": "cold"})
    w.sample(w.family("warm_fraction", "gauge",
                      "Fraction of dispatched instances that were "
                      "warm-started."), v["warm_fraction"])
    n = w.family("warm_rounds_saved_ewma", "gauge",
                 "EWMA of solver rounds saved per warm solve vs the "
                 "kind's cold baseline, by kind.")
    for kind, val in sorted(v["rounds_saved_ewma"].items()):
        w.sample(n, val, {"kind": kind})


def _per_kind_ewma(name: str, help_: str):
    def render(w: _Writer, v: dict) -> None:
        n = w.family(name, "gauge", help_)
        for kind, val in sorted(v.items()):
            w.sample(n, val, {"kind": kind})
    return render


_RENDERERS = {
    "queue_depth": _r_queue_depth,
    "tickets": _r_tickets,
    "flushes_by_trigger": _r_flushes,
    "dispatches": _r_dispatches,
    "latency_ms": _r_latency,
    "latency_samples": _r_latency_samples,
    "compact_cycles": _r_compact_cycles,
    "compact_live_mean": _r_compact_live_mean,
    "refill": _r_refill,
    "warm": _r_warm,
    "spread_ewma": _per_kind_ewma(
        "spread_ewma", "EWMA of per-bucket convergence spread, by kind "
        "(the adaptive-dispatch signal)."),
    "occupancy_ewma": _per_kind_ewma(
        "occupancy_ewma", "EWMA of batch occupancy (real/max_batch), "
        "by kind."),
    "rounds_ewma": _per_kind_ewma(
        "rounds_ewma", "EWMA of per-dispatch mean solver rounds, "
        "by kind."),
    "heuristics_ewma": _per_kind_ewma(
        "heuristics_ewma", "EWMA of per-dispatch mean heuristic "
        "invocations, by kind."),
}


def prometheus_text(metrics) -> str:
    """Render ``metrics`` (a ``SchedulerMetrics`` or a ``snapshot()``
    dict) in the Prometheus text exposition format.

    Raises ``KeyError`` for snapshot keys without a registered renderer —
    adding a field to the snapshot REQUIRES teaching the exposition about
    it (see module docstring).
    """
    snap = metrics.snapshot() if hasattr(metrics, "snapshot") else metrics
    w = _Writer()
    unknown = [k for k in snap if k not in _RENDERERS]
    if unknown:
        raise KeyError(
            f"snapshot keys {unknown} have no Prometheus renderer; add "
            f"them to repro.obs.export._RENDERERS")
    for key, render in _RENDERERS.items():
        if key in snap:
            render(w, snap[key])
    return "\n".join(w.lines) + "\n"
