"""repro.obs — tracing and telemetry for the serving/solver stack.

Three surfaces (docs/observability.md is the usage guide):

* SPANS — ``Tracer`` records per-request lifecycle spans
  (submit/queue-wait/bucket-pad/device-solve/refill-admission/resolve)
  through the instrumented engines; export with ``Tracer.save`` (Chrome
  trace, Perfetto-loadable) or read ``Tracer.spans()`` directly.  Install
  ambiently with ``use_tracer`` (engines capture it at construction) or
  pass ``tracer=`` explicitly.
* CYCLE EVENTS — ``repro.core.solver_loop.cycle_events`` streams
  structured per-cycle telemetry (live counts, rounds, heuristic
  invocations, compaction gathers) from both solver-loop drivers.
* METRICS EXPORT — ``prometheus_text`` renders a ``SchedulerMetrics``
  snapshot in the Prometheus text exposition format;
  ``step_annotation`` lines device timelines up with host spans under
  the jax profiler.

Disabled observability is free by construction: every hook is a single
``None``/contextvar check and results are bit-identical with tracing on
or off (tests/test_obs.py).
"""
from repro.obs.export import prometheus_text
from repro.obs.trace import (Span, Tracer, current_tracer, load_trace,
                             step_annotation, use_tracer)

__all__ = [
    "Span",
    "Tracer",
    "current_tracer",
    "load_trace",
    "prometheus_text",
    "step_annotation",
    "use_tracer",
]
