"""Training step builder: loss, grads, microbatching, optimizer update."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Sharder, softmax_cross_entropy
from repro.models.model import apply_model
from repro.optim.adamw import (AdamWConfig, OptState, apply_updates,
                               init_opt_state)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    num_microbatches: int = 1
    grad_dtype: str = "f32"          # "bf16" halves cross-pod gradient bytes
    z_loss: float = 1e-4


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def loss_fn(params, axes, cfg: ModelConfig, shd: Sharder, batch,
            z_loss=1e-4):
    out = apply_model(params, axes, cfg, shd, batch)
    labels = batch["labels"]
    per_tok = softmax_cross_entropy(out.logits, labels, z_loss=z_loss)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    loss = jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "tokens": jnp.sum(mask)}


def make_train_step(cfg: ModelConfig, axes, tcfg: TrainConfig, shd: Sharder):
    """Returns train_step(state, batch) -> (state, metrics), pjit-ready."""
    gdt = jnp.bfloat16 if tcfg.grad_dtype == "bf16" else jnp.float32

    def grads_of(params, batch):
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, axes, cfg, shd, batch, z_loss=tcfg.z_loss)
        return loss, aux, g

    def train_step(state: TrainState, batch):
        if tcfg.num_microbatches > 1:
            mb = tcfg.num_microbatches
            split = jax.tree.map(
                lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]),
                batch)

            def micro(carry, b):
                g_acc, loss_acc = carry
                loss, _, g = grads_of(state.params, b)
                g = jax.tree.map(lambda a, x: a + x.astype(gdt), g_acc, g)
                return (g, loss_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt),
                              state.params)
            (g, loss), _ = jax.lax.scan(micro, (g0, 0.0), split)
            g = jax.tree.map(lambda x: x / mb, g)
            loss = loss / mb
            aux = {"loss": loss, "tokens": jnp.float32(0)}
        else:
            loss, aux, g = grads_of(state.params, batch)
            g = jax.tree.map(lambda x: x.astype(gdt), g)

        new_params, new_opt, om = apply_updates(
            tcfg.optimizer, state.params, g, state.opt)
        metrics = {**aux, **om}
        return TrainState(new_params, new_opt), metrics

    return train_step


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, params
                     ) -> TrainState:
    return TrainState(params=params,
                      opt=init_opt_state(tcfg.optimizer, params))
