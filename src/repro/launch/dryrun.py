import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh, print memory/cost analysis, and emit roofline rows.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs.base import get_config, list_configs      # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.launch.specs import (SHAPES, build_cell,          # noqa: E402
                                cell_skip_reason)
from repro.roofline import (Roofline, cost_analysis_dict,    # noqa: E402
                            model_flops_for)
from repro.roofline_hlo import analyze as analyze_hlo        # noqa: E402

LM_ARCHS = [a for a in [
    "nemotron-4-340b", "minitron-8b", "smollm-135m", "command-r-plus-104b",
    "hubert-xlarge", "deepseek-v2-236b", "phi3.5-moe-42b-a6.6b",
    "mamba2-370m", "jamba-v0.1-52b", "chameleon-34b"]]


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             router_override=None, remat_override=None,
             microbatches: int = 1, grad_dtype: str = "f32",
             quantize_moments: bool = False, kv_quant: bool = False,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    skip = cell_skip_reason(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape, "status": "skip",
                "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    try:
        from repro.optim.adamw import AdamWConfig
        from repro.train.step import TrainConfig
        tcfg = TrainConfig(num_microbatches=microbatches,
                           grad_dtype=grad_dtype,
                           optimizer=AdamWConfig(
                               quantize_moments=quantize_moments))
        cell = build_cell(arch, shape, mesh,
                          router_override=router_override,
                          remat_override=remat_override,
                          kv_quant=kv_quant, tcfg=tcfg)
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings,
                             donate_argnums=cell.donate_argnums)
            lowered = jitted.lower(*cell.args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)  # list-vs-dict across jax versions
        hlo = compiled.as_text()
        acc = analyze_hlo(hlo)           # trip-count-exact (per device)
        coll = acc["collectives"]
        flops = acc["flops"]
        bytes_acc = acc["bytes"]
        bpd = float(getattr(mem, "temp_size_in_bytes", 0) +
                    getattr(mem, "argument_size_in_bytes", 0) +
                    getattr(mem, "output_size_in_bytes", 0) -
                    getattr(mem, "alias_size_in_bytes", 0))
        rl = Roofline(
            arch=arch, shape=shape,
            mesh="2x16x16" if multi_pod else "16x16", chips=chips,
            flops=flops, bytes_accessed=bytes_acc,
            coll_bytes=float(sum(coll.values())), coll_breakdown=coll,
            model_flops=model_flops_for(cfg, SHAPES[shape]),
            bytes_per_chip=bpd)
        out = {
            "arch": arch, "shape": shape, "status": "ok",
            "mesh": rl.mesh, "chips": chips,
            "compile_s": round(time.time() - t0, 1),
            "flops_per_chip": flops, "bytes_per_chip_accessed": bytes_acc,
            "collective_bytes_per_chip": rl.coll_bytes,
            "coll_breakdown": coll,
            "cost_analysis_flops": float(cost.get("flops", 0.0)),
            "cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
            "bytes_per_chip": bpd,
            "t_compute_ms": rl.t_compute * 1e3,
            "t_memory_ms": rl.t_memory * 1e3,
            "t_collective_ms": rl.t_collective * 1e3,
            "bottleneck": rl.bottleneck,
            "model_flops": rl.model_flops,
            "useful_flops_frac": rl.useful_flops_frac,
            "roofline_frac": rl.roofline_frac,
            "note": cell.note,
        }
        if verbose:
            print(f"[ok] {arch}/{shape} mesh={rl.mesh} "
                  f"compile={out['compile_s']}s "
                  f"mem/chip={bpd/2**30:.2f}GiB "
                  f"t=(c{rl.t_compute*1e3:.1f}|m{rl.t_memory*1e3:.1f}|"
                  f"x{rl.t_collective*1e3:.1f})ms "
                  f"bottleneck={rl.bottleneck} "
                  f"roofline={rl.roofline_frac:.2f}")
            print(f"     memory_analysis: {mem}")
        return out
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        if verbose:
            traceback.print_exc()
        return {"arch": arch, "shape": shape, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "compile_s": round(time.time() - t0, 1)}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--router", default=None,
                    choices=[None, "topk", "flow"])
    ap.add_argument("--remat", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-dtype", default="f32")
    ap.add_argument("--quantize-moments", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in LM_ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for a, s in cells:
        results.append(run_cell(a, s, multi_pod=args.multi_pod,
                                router_override=args.router,
                                remat_override=args.remat,
                                microbatches=args.microbatches,
                                grad_dtype=args.grad_dtype,
                                quantize_moments=args.quantize_moments,
                                kv_quant=args.kv_quant))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if r["status"] == "error"]
    print(f"\n{len(results)} cells: "
          f"{sum(r['status']=='ok' for r in results)} ok, "
          f"{sum(r['status']=='skip' for r in results)} skip, "
          f"{len(bad)} error")
    for r in bad:
        print(f"  ERROR {r['arch']}/{r['shape']}: {r['error']}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
