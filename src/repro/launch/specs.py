"""Per-(arch × input-shape) dry-run cell builder.

For every cell this produces: the step function (train / prefill / decode),
ShapeDtypeStruct stand-ins for all its inputs (no device allocation), and
NamedShardings for in_shardings — everything ``dryrun.py`` needs to
``.lower().compile()`` on the production mesh.

Logical-axes trees are obtained from a *tiny same-structure variant* (real
init, <1M params) — the axes values depend only on the config's structure,
never on its sizes — while the full-size ShapeDtypeStructs come from
``jax.eval_shape`` (abstract, no allocation even for 340B params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, get_config, smoke_variant
from repro.models.layers import Sharder, DEFAULT_RULES
from repro.models.model import apply_model, init_caches, init_model
from repro.serve.engine import ServeState, make_prefill_step, make_serve_step
from repro.train.step import (TrainConfig, TrainState, init_train_state,
                              make_train_step)

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


class Cell(NamedTuple):
    fn: Any                  # callable to jit
    args: tuple              # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any       # None -> compiler-chosen
    donate_argnums: tuple
    note: str


def cell_skip_reason(cfg: ModelConfig, shape_name: str) -> str | None:
    if cfg.family == "encoder" and SHAPES[shape_name]["kind"] == "decode":
        return "encoder-only: no decode step"
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return "full quadratic attention: 500k infeasible (DESIGN.md §6)"
    return None


def model_axes(cfg: ModelConfig):
    """Axes tree via a tiny same-structure init (values are size-free)."""
    _, axes = init_model(smoke_variant(cfg), jax.random.PRNGKey(0))
    return axes


def cache_axes_of(cfg: ModelConfig):
    _, ca = init_caches(smoke_variant(cfg), B=1, S_max=8)
    return ca


def _tree_specs(shd: Sharder, tree, axes_tree):
    leaves, tdef = jax.tree.flatten(tree)
    alist = tdef.flatten_up_to(axes_tree)
    return jax.tree.unflatten(
        tdef, [shd.spec(l.shape, a) for l, a in zip(leaves, alist)])


def _opt_moment_specs(shd: Sharder, m_tree, axes_tree):
    """Specs for Adam moments: like the params, but 8-bit-quantized leaves
    (Quantized(q, scale)) shard their leading dims like the param and
    replicate the trailing (block, BLOCK) payload dims."""
    from repro.optim.adamw import Quantized
    from repro.models.model import _is_axes

    a_leaves, a_def = jax.tree.flatten(axes_tree, is_leaf=_is_axes)
    m_leaves = a_def.flatten_up_to(m_tree)

    def spec_of(m, a):
        if isinstance(m, Quantized):
            qa = tuple(a[:-1]) + (None, None)
            sa = tuple(a[:-1]) + (None, None)
            return Quantized(shd.spec(m.q.shape, qa),
                             shd.spec(m.scale.shape, sa))
        return shd.spec(m.shape, a)

    return jax.tree.unflatten(
        a_def, [spec_of(m, a) for m, a in zip(m_leaves, a_leaves)])


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def resolve_config(arch: str, router_override=None, remat_override=None,
                   kv_quant: bool = False):
    cfg = get_config(arch)
    if router_override and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, router=router_override))
    if remat_override:
        cfg = dataclasses.replace(cfg, remat=remat_override)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    return cfg


def build_cell(arch: str, shape_name: str, mesh, *,
               param_dtype=jnp.bfloat16, router_override: str | None = None,
               remat_override: str | None = None, kv_quant: bool = False,
               tcfg: TrainConfig | None = None) -> Cell:
    cfg = resolve_config(arch, router_override, remat_override, kv_quant)
    info = SHAPES[shape_name]
    S, B = info["seq_len"], info["global_batch"]
    shd = Sharder(mesh, DEFAULT_RULES)
    tcfg = tcfg or TrainConfig()

    params_sds = jax.eval_shape(
        lambda k: init_model(cfg, k, dtype=param_dtype)[0],
        jax.random.PRNGKey(0))
    axes = model_axes(cfg)
    p_specs = _tree_specs(shd, params_sds, axes)
    batch_axes = ("batch", None)

    if info["kind"] == "train":
        state_sds = jax.eval_shape(
            lambda p: init_train_state(cfg, tcfg, p), params_sds)
        s_specs = TrainState(
            params=p_specs,
            opt=type(state_sds.opt)(
                step=P(),
                m=_opt_moment_specs(shd, state_sds.opt.m, axes),
                v=_opt_moment_specs(shd, state_sds.opt.v, axes)))
        if cfg.frontend_dim:
            batch_sds = {
                "embeds": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim),
                                               jnp.float32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            b_specs = {"embeds": shd.spec((B, S, cfg.frontend_dim),
                                          ("batch", None, None)),
                       "labels": shd.spec((B, S), batch_axes)}
        else:
            batch_sds = {
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            b_specs = {k: shd.spec((B, S), batch_axes) for k in batch_sds}
        fn = make_train_step(cfg, axes, tcfg, shd)
        # out = (new_state, metrics): aliasing the donated state requires
        # matching out_shardings; metrics stay compiler-chosen.
        out_sh = (_named(mesh, s_specs), None)
        return Cell(fn, (state_sds, batch_sds),
                    (_named(mesh, s_specs), _named(mesh, b_specs)),
                    out_sh, (0,), f"{arch}/{shape_name}: train_step")

    caches_sds = jax.eval_shape(
        lambda: init_caches(cfg, B, S, dtype=jnp.bfloat16)[0])
    cache_axes = cache_axes_of(cfg)
    c_specs = _tree_specs(shd, caches_sds, cache_axes)

    if info["kind"] == "prefill":
        if cfg.frontend_dim:
            # encoder "prefill" = full forward classification at length S
            def fn(params, embeds):
                return apply_model(params, axes, cfg, shd,
                                   {"embeds": embeds}).logits
            args = (params_sds,
                    jax.ShapeDtypeStruct((B, S, cfg.frontend_dim),
                                         jnp.float32))
            in_sh = (_named(mesh, p_specs),
                     _named(mesh, shd.spec((B, S, cfg.frontend_dim),
                                           ("batch", None, None))))
            return Cell(fn, args, in_sh, None, (),
                        f"{arch}/{shape_name}: encoder forward")
        fn = make_prefill_step(cfg, axes, cache_axes, shd)
        args = (params_sds, jax.ShapeDtypeStruct((B, S), jnp.int32),
                caches_sds)
        in_sh = (_named(mesh, p_specs),
                 _named(mesh, shd.spec((B, S), batch_axes)),
                 _named(mesh, c_specs))
        return Cell(fn, args, in_sh, None, (2,),
                    f"{arch}/{shape_name}: prefill")

    # decode: cache holds seq_len-1 tokens, serve_step appends one
    serve = make_serve_step(cfg, axes, shd, pos_offset=S - 1)
    state_sds = ServeState(
        caches=caches_sds,
        last_tokens=jax.ShapeDtypeStruct((B,), jnp.int32),
        lengths=jax.ShapeDtypeStruct((B,), jnp.int32))
    s_specs = ServeState(caches=c_specs,
                         last_tokens=shd.spec((B,), ("batch",)),
                         lengths=shd.spec((B,), ("batch",)))
    return Cell(serve, (params_sds, state_sds),
                (_named(mesh, p_specs), _named(mesh, s_specs)),
                (None, _named(mesh, s_specs)), (1,),
                f"{arch}/{shape_name}: serve_step (decode)")
