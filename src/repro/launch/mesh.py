"""Mesh construction + sharding specs for the solver stack and the models.

Every factory is a function (not a constant): importing this module must
never touch jax device state.

Two mesh families live here:

* model meshes (``make_production_mesh`` / ``make_host_mesh``) — the
  ``("data", "model")`` meshes the transformer stack shards over, and
* solver meshes (``make_solver_mesh``) — a 1-D ``("batch",)`` mesh for the
  batched flow/matching solvers, whose batch axis is embarrassingly
  data-parallel (per-instance liveness masks make every instance's
  trajectory independent of its batch-mates, so shards never communicate).

``shard_batched`` is the one sharding primitive the solver stack uses: it
wraps a batch-leading function in ``shard_map`` with the leading axis
partitioned across the mesh and everything else replicated. Because the
wrapped solvers contain no collectives, each device runs its local shard's
while-loops to local convergence — a fully-converged shard simply finishes
its dispatch early. Results bit-match the unsharded batched solve
(tests/test_shard.py).

``compact_lanes`` is the compaction analogue: it splits the batch into
per-shard host-driven lanes (one per device) for the solvers' ``compact=``
paths, keeping early-exit compaction within each shard (tests/test_compact.py).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import numpy as np
from jax.sharding import PartitionSpec


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod prepends a 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this process actually has (smoke tests, examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def make_solver_mesh(n_devices: int | None = None, *, axis: str = "batch"):
    """1-D device mesh for batch-axis sharding of the batched solvers.

    Args:
      n_devices: how many local devices to use (default: all). Emulate a
        multi-device host on CPU with
        ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
      axis: mesh axis name; the solvers' default sharding axis is "batch".

    Returns a ``jax.sharding.Mesh`` accepted by the ``mesh=`` knob of
    every registered solver kind's batched entry point
    (``maxflow_grid_batch`` / ``solve_assignment`` /
    ``match_bipartite_batch`` / ...), of the generic ragged front end
    ``repro.core.batch.solve_batch``, and of the serving engines
    (``repro.serve``).
    """
    devs = jax.devices()
    if n_devices is not None:
        if not 1 <= n_devices <= len(devs):
            raise ValueError(
                f"n_devices={n_devices} outside [1, {len(devs)}] available")
        devs = devs[:n_devices]
    return jax.sharding.Mesh(np.array(devs), (axis,))


def solver_batch_axis(mesh, mesh_axis: str | None = None) -> str:
    """The mesh axis the batch dimension shards over (default: first axis)."""
    axis = mesh_axis if mesh_axis is not None else mesh.axis_names[0]
    if axis not in mesh.axis_names:
        raise ValueError(f"axis {axis!r} not in mesh axes {mesh.axis_names}")
    return axis


def shard_count(mesh, mesh_axis: str | None = None) -> int:
    """Number of shards the batch axis splits into on ``mesh``."""
    return int(mesh.shape[solver_batch_axis(mesh, mesh_axis)])


def batch_spec(mesh, mesh_axis: str | None = None) -> PartitionSpec:
    """PartitionSpec sharding a leading batch axis; trailing axes replicate.

    Used as a pytree-prefix spec: one ``PartitionSpec("batch")`` covers every
    leaf of the solvers' problem/result pytrees, because every public leaf
    leads with the batch axis.
    """
    return PartitionSpec(solver_batch_axis(mesh, mesh_axis))


def compact_lanes(mesh, mesh_axis: str | None, batch_size: int):
    """Per-shard ``(lo, hi, device)`` lanes for compacted solving on ``mesh``.

    Early-exit compaction (``repro.core.solver_loop.run_compacted``) under a
    mesh stays WITHIN each shard: every shard becomes an independent
    host-driven compaction lane pinned to its device, instances never
    migrate between shards, and no collectives are introduced — so the
    shard-independence contract (and the bit-match with the unsharded and
    masked paths) is preserved. Requires one device per shard, i.e. the 1-D
    solver meshes of ``make_solver_mesh``.
    """
    n = shard_count(mesh, mesh_axis)
    if batch_size % n:
        raise ValueError(
            f"batch size {batch_size} not divisible by shard count "
            f"{n}; pad the batch (repro.core.batch does this "
            f"automatically)")
    if int(mesh.devices.size) != n:
        raise ValueError(
            f"compact=True needs one device per shard (a 1-D solver mesh); "
            f"this mesh has {int(mesh.devices.size)} devices for {n} shards")
    per = batch_size // n
    devs = list(mesh.devices.reshape(-1))
    return [(i * per, (i + 1) * per, devs[i]) for i in range(n)]


def scheduler_lanes(mesh, mesh_axis: str | None = None, n_lanes: int = 2):
    """Per-lane meshes for the async scheduler's double-buffered dispatch.

    The serving scheduler (``repro.serve.scheduler.AsyncSolverEngine``)
    keeps ``n_lanes`` dispatch lanes so batch *k+1*'s host padding overlaps
    batch *k*'s device solve. This helper decides what each lane dispatches
    ON:

    * ``mesh is None`` — every lane gets ``None`` (default device; overlap
      is host-vs-device pipelining only).
    * mesh with >= ``n_lanes`` devices — the mesh's devices split into
      ``n_lanes`` contiguous DISJOINT sub-meshes (remainder devices go to
      the leading lanes), so two in-flight batches run on different
      hardware concurrently, not just back-to-back in one device queue.
    * fewer devices than lanes — every lane shares the full mesh.

    Results are unaffected either way: sharded solves bit-match unsharded
    ones (tests/test_shard.py), so WHICH sub-mesh a batch lands on never
    changes its values. Requires a 1-D solver mesh (``make_solver_mesh``).
    """
    if n_lanes < 1:
        raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
    if mesh is None:
        return [None] * n_lanes
    axis = solver_batch_axis(mesh, mesh_axis)
    devs = list(mesh.devices.reshape(-1))
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"scheduler_lanes needs a 1-D solver mesh, got axes "
            f"{mesh.axis_names}")
    if len(devs) < n_lanes:
        return [mesh] * n_lanes
    per, rem = divmod(len(devs), n_lanes)
    lanes, lo = [], 0
    for i in range(n_lanes):
        hi = lo + per + (1 if i < rem else 0)
        lanes.append(jax.sharding.Mesh(np.array(devs[lo:hi]), (axis,)))
        lo = hi
    return lanes


def shard_batched(fn: Callable, mesh, mesh_axis: str | None = None):
    """Wrap a batch-leading ``fn`` so the batch axis splits across ``mesh``.

    ``fn`` must take array/pytree arguments whose every leaf has the batch
    dimension leading, and return a pytree with the same property. The
    returned callable is ``jit(shard_map(fn))`` with the batch axis
    partitioned and no replication checking (the solvers are collective-free,
    every output is sharded).

    The caller is responsible for ``B % shard_count(mesh) == 0``; the core
    entry points raise a ``ValueError`` otherwise and the pad-and-bucket
    front end pads with inert instances instead.
    """
    try:  # stable namespace (newer jax); experimental alias as fallback
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map

    spec = batch_spec(mesh, mesh_axis)
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec,
                             check_rep=False))


@functools.lru_cache(maxsize=None)
def _cached_shard_batched(impl: Callable, mesh, mesh_axis, kw_items: tuple):
    return shard_batched(functools.partial(impl, **dict(kw_items)),
                         mesh, mesh_axis)


def dispatch_sharded(impl: Callable, args: tuple, batch_size: int, mesh,
                     mesh_axis: str | None, **static_kw):
    """Run batched ``impl(*args, **static_kw)`` with the batch axis sharded.

    The one mesh-dispatch funnel the solvers' ``mesh=`` paths share:
    validates ``batch_size`` divides the shard count, memoizes the
    jit(shard_map(...)) callable per (impl, mesh, mesh_axis, kwargs), and
    calls it. ``impl`` must be hashable (a module-level function) and
    ``static_kw`` values hashable.
    """
    n_shards = shard_count(mesh, mesh_axis)
    if batch_size % n_shards:
        raise ValueError(
            f"batch size {batch_size} not divisible by shard count "
            f"{n_shards}; pad the batch (repro.core.batch does this "
            f"automatically)")
    fn = _cached_shard_batched(impl, mesh, mesh_axis,
                               tuple(sorted(static_kw.items())))
    return fn(*args)
