"""Serving driver: batched prefill + greedy decode on the host mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, smoke_variant
from repro.launch.mesh import make_host_mesh
from repro.models.layers import Sharder, DEFAULT_RULES
from repro.models.model import init_caches, init_model
from repro.serve.engine import make_prefill_step, make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    assert cfg.family != "encoder", "encoder archs have no decode path"

    mesh = make_host_mesh(args.model_parallel)
    shd = Sharder(mesh, DEFAULT_RULES)
    params, axes = init_model(cfg, jax.random.PRNGKey(args.seed))

    B, S = args.batch, args.prompt_len
    S_max = S + args.max_new
    key = jax.random.PRNGKey(args.seed + 1)
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)
    caches, _ = init_caches(cfg, B, S_max, dtype=jnp.float32)

    with mesh:
        prefill = jax.jit(make_prefill_step(cfg, axes, None, shd))
        t0 = time.time()
        nxt, state = prefill(params, prompts, caches)
        nxt.block_until_ready()
        t_prefill = time.time() - t0

        step = jax.jit(make_serve_step(cfg, axes, shd))  # position traced
        toks = [nxt]
        t0 = time.time()
        for _ in range(args.max_new - 1):
            nxt, state = step(params, state)
            toks.append(nxt)
        jax.block_until_ready(toks[-1])
        t_decode = time.time() - t0

    out = jnp.stack(toks, axis=1)
    print(f"prefill: {B}x{S} in {t_prefill*1e3:.0f}ms "
          f"({B*S/t_prefill:.0f} tok/s)")
    print(f"decode: {args.max_new - 1} steps in {t_decode*1e3:.0f}ms "
          f"({B*(args.max_new-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample generations (token ids):")
    for b in range(min(B, 2)):
        print(f"  req{b}: {out[b, :12].tolist()}")


if __name__ == "__main__":
    main()
