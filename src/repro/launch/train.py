"""End-to-end training driver: data -> train_step -> checkpoint/restart.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1 --resume auto

Runs on whatever devices the process has (CPU smoke-scale included); the
same code path drives the production mesh under a multi-host launcher —
jax.distributed.initialize() is called when JAX_COORDINATOR is set.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import store
from repro.configs.base import get_config, smoke_variant
from repro.data.pipeline import DataConfig, make_global_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import model_axes, _tree_specs, _named
from repro.models.layers import Sharder, DEFAULT_RULES
from repro.models.model import init_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.ft import PreemptionGuard, StepWatchdog
from repro.train.step import (TrainConfig, TrainState, init_train_state,
                              make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--router", default=None)
    ap.add_argument("--grad-dtype", default="f32")
    ap.add_argument("--quantize-moments", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()   # multi-host path

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if args.router and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, router=args.router))

    mesh = make_host_mesh(args.model_parallel)
    shd = Sharder(mesh, DEFAULT_RULES)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr_peak=args.lr, warmup_steps=20,
                              decay_steps=args.steps,
                              quantize_moments=args.quantize_moments),
        num_microbatches=args.microbatches, grad_dtype=args.grad_dtype)

    params, axes = init_model(cfg, jax.random.PRNGKey(args.seed))
    state = init_train_state(cfg, tcfg, params)
    p_specs = _tree_specs(shd, params, axes)
    s_specs = TrainState(
        params=p_specs,
        opt=type(state.opt)(step=P(),
                            m=jax.tree.map(lambda _: P(), state.opt.m),
                            v=jax.tree.map(lambda _: P(), state.opt.v)))
    s_sh = _named(mesh, s_specs)
    state = jax.device_put(state, s_sh)

    start_step = 0
    if args.resume == "auto" and args.ckpt_dir:
        latest = store.latest_step(args.ckpt_dir)
        if latest is not None:
            state = store.restore(args.ckpt_dir, latest, state, s_sh)
            start_step = latest
            print(f"[resume] restored step {latest} from {args.ckpt_dir}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed,
                      frontend_dim=cfg.frontend_dim)
    batch_sh = NamedSharding(mesh, shd.spec((args.batch, args.seq),
                                            ("batch", None)))
    emb_sh = NamedSharding(
        mesh, shd.spec((args.batch, args.seq, max(cfg.frontend_dim, 1)),
                       ("batch", None, None)))

    step_fn = jax.jit(make_train_step(cfg, axes, tcfg, shd),
                      donate_argnums=(0,))
    watchdog = StepWatchdog()
    with PreemptionGuard() as guard, mesh:
        for step in range(start_step, args.steps):
            batch = make_global_batch(
                dcfg, step, emb_sh if cfg.frontend_dim else batch_sh)
            if cfg.frontend_dim:
                batch["labels"] = jax.device_put(batch["labels"], batch_sh)
            watchdog.start()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            slow = watchdog.stop(step)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step}: loss={loss:.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.2f} "
                      f"t={watchdog.times[-1]*1e3:.0f}ms"
                      + (" [STRAGGLER]" if slow else ""))
            want_ckpt = args.ckpt_dir and (
                (step + 1) % args.ckpt_every == 0 or guard.requested
                or step == args.steps - 1)
            if want_ckpt:
                path = store.save(args.ckpt_dir, step + 1, state)
                print(f"[ckpt] step {step + 1} -> {path}")
            if guard.requested:
                print("[preempt] checkpoint written, exiting cleanly")
                return
    if watchdog.slow_steps:
        print(f"[watchdog] {len(watchdog.slow_steps)} straggler steps "
              f"(median {watchdog.median*1e3:.0f}ms)")


if __name__ == "__main__":
    main()
