"""AdamW with ZeRO-sharded states, optional 8-bit moment quantization.

States inherit the parameter's sharding (FSDP axis), so the optimizer is
ZeRO-1/3 style by construction. ``quantize_moments=True`` stores m/v as int8
with a per-last-axis-block fp32 scale — a distributed-optimization memory
trick (8-bit Adam) that cuts optimizer bytes 4x; the dequant/requant round
trip happens inside the (already memory-bound) update, so it is free on the
roofline's compute term.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False


class Quantized(NamedTuple):
    q: jax.Array          # int8 payload
    scale: jax.Array      # fp32 per-block scales


def _quantize(x: jax.Array) -> Quantized:
    pad = (-x.shape[-1]) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = xp.reshape(*xp.shape[:-1], -1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return Quantized(q, scale.astype(jnp.float32))


def _dequantize(qv: Quantized, shape) -> jax.Array:
    x = (qv.q.astype(jnp.float32) * qv.scale).reshape(*qv.q.shape[:-2], -1)
    return x[..., :shape[-1]].reshape(shape)


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def lr_schedule(cfg: AdamWConfig, step):
    warm = cfg.lr_peak * (step + 1) / cfg.warmup_steps
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * \
        (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(cfg: AdamWConfig, params) -> OptState:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _quantize(z) if cfg.quantize_moments and p.ndim >= 1 \
            and p.size >= BLOCK else z
    return OptState(step=jnp.int32(0),
                    m=jax.tree.map(zero_like, params),
                    v=jax.tree.map(zero_like, params))


# v (second moment) is quantized in sqrt-space: its dynamic range spans many
# decades and symmetric int8 floors small entries to zero, which explodes
# the update denominator (observed: quadratic-fit loss 48 vs 0.4). sqrt
# compresses the range so 127 levels give <1% error on the denominator.


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = lr_schedule(cfg, state.step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        quantized = isinstance(m, Quantized)
        if quantized:
            m = _dequantize(m, p.shape)
            v = _dequantize(v, p.shape) ** 2      # stored as sqrt(v)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (u + cfg.weight_decay *
                                              p.astype(jnp.float32))
        if quantized:
            m, v = _quantize(m), _quantize(jnp.sqrt(v))
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_params, OptState(step, new_m, new_v), \
        {"lr": lr, "grad_norm": gnorm}
