"""HuBERT-XLarge [arXiv:2106.07447]: encoder-only audio transformer.

The conv waveform frontend is a STUB: ``input_specs()`` provides precomputed
512-dim frame embeddings (the conv extractor's output width); the model
projects them to d_model. vocab=504 is the k-means codebook (masked-frame
prediction targets). Encoder-only: no decode shapes.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, head_dim=80,
    mlp_act="gelu", gated_mlp=False, norm="layernorm",
    causal=False, rope_theta=0.0,            # conv-pos stub -> sinusoidal
    frontend_dim=512, sub_quadratic=False,
    source="arXiv:2106.07447 (unverified)",
))
