"""Jamba-v0.1 52B [arXiv:2403.19887]: Mamba+attention 1:7, 16-expert MoE.

Period of 8 layers: one attention layer per 8 (index 0 of each period in
this implementation; the released model uses index 4 — roofline-identical),
MoE every other layer. Mamba sublayers use d_state=16 (Jamba v0.1 is
Mamba-1; we realize them with the SSD block at N=16 — see DESIGN.md §2).
Sub-quadratic: runs long_500k (attention decode is linear in cache length).
"""
from repro.configs.base import MoEConfig, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65_536, head_dim=128,
    attn_period=8,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk=256),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336,
                  router="flow", every=2),
    mlp_act="silu", gated_mlp=True,
    rope_theta=0.0,                          # jamba uses no positional emb
    sub_quadratic=True,
    source="arXiv:2403.19887 (hf)",
))
