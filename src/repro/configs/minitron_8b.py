"""Minitron-8B [arXiv:2407.14679]: pruned Nemotron (squared-ReLU, GQA)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab=256_000, head_dim=128,
    mlp_act="relu2", gated_mlp=False, norm="layernorm",
    rope_theta=10_000.0, sub_quadratic=False,
    source="arXiv:2407.14679 (hf)",
))
