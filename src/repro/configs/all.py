"""Imports every architecture config so the registry is populated."""
from repro.configs import (nemotron_4_340b, minitron_8b, smollm_135m,  # noqa
                           command_r_plus_104b, hubert_xlarge,
                           deepseek_v2_236b, phi35_moe_42b, mamba2_370m,
                           jamba_v01_52b, chameleon_34b, paper_flow)
