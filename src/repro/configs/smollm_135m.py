"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M]: llama-arch small, GQA kv=3."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab=49_152, head_dim=64,
    mlp_act="silu", gated_mlp=True, tie_embeddings=True,
    rope_theta=10_000.0, sub_quadratic=False,
    source="hf:HuggingFaceTB/SmolLM-135M",
))
