"""Chameleon-34B [arXiv:2405.09818]: early-fusion VLM, VQ image tokens.

The image frontend (VQ-GAN tokenizer) is a STUB: images arrive as discrete
tokens inside the shared 65536 vocab, so the backbone is a plain decoder
with qk-norm. long_500k skipped: pure quadratic full attention.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65_536, head_dim=128,
    mlp_act="silu", gated_mlp=True, qk_norm=True,
    rope_theta=10_000.0, sub_quadratic=False,
    source="arXiv:2405.09818 (unverified)",
))
