"""Phi-3.5-MoE 42B (6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab=32_064, head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400,
                  router="flow", every=1),
    mlp_act="silu", gated_mlp=True, norm="layernorm",
    rope_theta=10_000.0, sub_quadratic=False,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
))
