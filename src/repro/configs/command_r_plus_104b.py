"""Command-R+ 104B [hf:CohereForAI]: dense GQA, no-bias, tied embeddings."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab=256_000, head_dim=128,
    mlp_act="silu", gated_mlp=True, tie_embeddings=True,
    norm="layernorm", qk_norm=True,          # cohere uses qk-norm (R+)
    rope_theta=75_000_000.0, sub_quadratic=False,
    source="hf:CohereForAI/c4ai-command-r-plus (unverified)",
))
