"""Mamba2-370M [arXiv:2405.21060]: attention-free SSD. Runs long_500k."""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50_280, attn_type="none",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True, rope_theta=0.0, sub_quadratic=True,
    source="arXiv:2405.21060 (unverified)",
))
