"""Nemotron-4-340B [arXiv:2402.16819]: dense GQA, squared-ReLU MLP."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab=256_000, head_dim=192,
    mlp_act="relu2", gated_mlp=False,        # squared-ReLU, ungated
    norm="layernorm",                        # nemotron uses LayerNorm
    rope_theta=10_000.0, sub_quadratic=False,
    source="arXiv:2402.16819 (unverified)",
))
