"""Architecture config dataclass + registry (``--arch <id>`` everywhere)."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

_REGISTRY: dict[str, "ModelConfig"] = {}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    router: str = "topk"          # "topk" | "flow" (paper technique)
    capacity_factor: float = 1.25
    every: int = 1                # MoE layer every `every` layers
    router_iters: int = 8         # auction rounds for router="flow"


@dataclasses.dataclass(frozen=True)
class MLAConfig:                  # DeepSeek multi-head latent attention
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:                  # Mamba2 SSD
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0             # 0 -> d_model // n_heads
    attn_type: str = "gqa"        # gqa | mla | none
    mlp_act: str = "silu"         # silu (=> SwiGLU) | relu2 | gelu
    gated_mlp: bool = True
    norm: str = "rmsnorm"
    causal: bool = True
    tie_embeddings: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    max_seq: int = 524_288
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_period: int = 1          # hybrid: attention layer every `period`
    n_dense_prefix: int = 0       # leading dense-FFN layers (deepseek: 1)
    frontend_dim: int = 0         # audio/vlm stubs: input embedding width
    sub_quadratic: bool = False   # can run long_500k
    remat: str = "full"           # full | dots | none
    kv_quant: bool = False        # int8 KV cache (GQA decode memory /2)
    # paper notes / provenance
    source: str = ""

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def param_count(self) -> int:
        """Approximate parameter count (reported in DESIGN/EXPERIMENTS)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = V * D * (1 if self.tie_embeddings else 2)
        n = emb
        for i in range(L):
            n += self._layer_params(i)
        return n

    def _layer_params(self, i: int) -> int:
        D, F = self.d_model, self.d_ff
        n = 2 * D                                      # norms
        is_attn = (i % self.attn_period == 0) if self.family == "hybrid" \
            else (self.attn_type != "none")
        if self.family == "ssm" or (self.family == "hybrid" and not is_attn):
            s = self.ssm
            di = s.d_inner(D)
            n += D * (2 * di + 2 * s.d_state + s.n_heads(D)) + di * D \
                + s.d_conv * (di + 2 * s.d_state)
        elif self.attn_type == "mla":
            m = self.mla
            qd = m.qk_nope_dim + m.qk_rope_dim
            n += D * m.q_lora_rank + m.q_lora_rank * self.n_heads * qd
            n += D * (m.kv_lora_rank + m.qk_rope_dim)
            n += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_dim)
            n += self.n_heads * m.v_dim * D
        elif self.attn_type != "none":
            dh = self.dh
            n += D * self.n_heads * dh + 2 * D * self.n_kv_heads * dh \
                + self.n_heads * dh * D
        # FFN / MoE
        moe_here = self.moe is not None and i >= self.n_dense_prefix and \
            ((i - self.n_dense_prefix) % self.moe.every == 0)
        if moe_here:
            e = self.moe
            per = D * e.d_ff_expert * (3 if self.gated_mlp else 2)
            n += (e.n_experts + e.n_shared) * per + D * e.n_experts
        elif F:
            n += D * F * (3 if self.gated_mlp else 2)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        D, L = self.d_model, self.n_layers
        n = self.vocab * D * (1 if self.tie_embeddings else 2)
        e = self.moe
        for i in range(L):
            full = self._layer_params(i)
            moe_here = i >= self.n_dense_prefix and \
                ((i - self.n_dense_prefix) % e.every == 0)
            if moe_here:
                per = D * e.d_ff_expert * (3 if self.gated_mlp else 2)
                full -= (e.n_experts - e.top_k) * per
            n += full
        return n


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        import repro.configs.all  # noqa: F401 (registers everything)
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs.all  # noqa: F401
    return sorted(_REGISTRY)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid"
                     else 2 * cfg.attn_period),
        d_model=128, d_ff=256 if cfg.d_ff else 0, vocab=128,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=32 if cfg.n_heads else 0, max_seq=512,
        name=cfg.name + "-smoke")
    if cfg.n_kv_heads == cfg.n_heads:       # MHA archs stay MHA
        kw["n_kv_heads"] = kw["n_heads"]
    if cfg.moe:
        # slack capacity: at smoke scale, tight capacity makes routing
        # depend on batch composition (full-vs-prefill token sets differ),
        # which breaks decode-consistency tests for reasons inherent to
        # capacity-routed MoE, not bugs. Production cf stays 1.25.
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64, capacity_factor=2.5)
    if cfg.mla:
        kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                              qk_nope_dim=16, qk_rope_dim=16, v_dim=16)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32,
                                        chunk=64)
    return dataclasses.replace(cfg, **kw)
