"""DeepSeek-V2 236B [arXiv:2405.04434]: MLA + 160-expert top-6 MoE.

MLA: kv_lora=512, q_lora=1536, qk 128 nope + 64 rope, v 128. First layer is
a dense FFN (12288), layers 1..59 are MoE with 2 shared + 160 routed experts
of d_ff 1536.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab=102_400,
    attn_type="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2,
                  router="flow", every=1),
    n_dense_prefix=1,
    mlp_act="silu", gated_mlp=True,
    rope_theta=10_000.0, sub_quadratic=False,
    source="arXiv:2405.04434 (hf)",
))
