"""The paper's own workloads (§4 grid cuts, §5 assignment) as configs."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class FlowBenchConfig:
    name: str
    kind: str                 # "grid_maxflow" | "assignment"
    grid: tuple = (512, 512)  # grid graph size (vision-scale, [4]'s datasets)
    n: int = 30               # assignment size (paper §6: |X|=|Y|<=30)
    max_cost: int = 100       # paper §6: costs <= 100


GRID_BENCH = FlowBenchConfig(name="paper-grid-maxflow", kind="grid_maxflow")
ASSIGN_BENCH = FlowBenchConfig(name="paper-assignment", kind="assignment")
