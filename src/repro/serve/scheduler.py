"""Async serving scheduler: background-flush SolverEngine with futures.

The blocking serve path (``repro.serve.engine.SolverEngine``) solves
nothing until a caller flushes, and while it pads the next queue the
device idles. This module puts a SCHEDULER in front of the same
synchronous core:

* ``AsyncSolverEngine.submit(kind, payload)`` may be called from any
  thread — for any kind registered with ``repro.core.kinds`` — and
  returns a ``concurrent.futures.Future``;
* a background scheduler thread flushes a kind when its queue reaches
  ``max_batch`` (size trigger) or the oldest request's deadline expires
  (deadline trigger, per-request ``deadline_ms`` with ``max_delay_ms`` as
  the default) — no manual flush ever needed;
* flushed batches run through a TWO-STAGE pipeline: the scheduler thread
  does the host-side pad-and-bucket (``SolverEngine.prepare``) of batch
  *k+1* while a lane thread runs the device solve
  (``SolverEngine.solve_prepared``) of batch *k*. Lanes are
  double-buffered (``n_lanes``, bounded hand-off queues — one staged and
  one in-flight dispatch per lane) and, on a multi-device mesh, dispatch
  onto disjoint sub-meshes (``repro.launch.mesh.scheduler_lanes``) so two
  batches overlap on hardware;
* per dispatch the scheduler picks the MASKED or COMPACTED solver-loop
  driver adaptively from the EWMA of recent batches' convergence spread,
  tracked PER KIND (``repro.serve.metrics.ConvergenceStats``;
  ``dispatch=`` forces either driver);
* with ``refill=True`` a flushed batch becomes a CONTINUOUS-BATCHING
  session (``repro.core.refill.RefillSolver``): queued requests of the
  same kind that fit the session's bucket shape are admitted into slots
  vacated by converged instances at every cycle boundary — mid-solve, not
  at the next flush — and each ticket's future resolves the moment ITS
  instance converges, not at batch drain.  Kinds without a registered
  refill runtime fall back to the closed-batch path unchanged; and
* every result is bit-identical to the synchronous ``flush()`` of the
  same queue — the scheduler only decides WHEN and ON WHICH DEVICES the
  tested batch path runs, never what it computes
  (tests/test_scheduler.py, tests/test_refill.py).

The scheduler itself is kind-agnostic: queues, triggers, EWMAs, and lane
dispatch are all keyed by the kind names that actually arrive, so a newly
registered solver kind (docs/solvers.md) serves through it with no change
here — tests/test_matching.py drives the ``"matching"`` kind through this
exact code path.

Failure semantics: requests are validated BEFORE a future exists (same
contract as the sync engine); if a batched dispatch still fails, the lane
falls back to solving that batch's requests one at a time so a poisoned
request fails ONLY its own future. ``close(drain=True)`` (also the
context-manager exit) solves everything pending before returning;
``close(drain=False)`` cancels queued futures (``Future.cancelled()``)
and only finishes batches already in flight. Neither path can hang on a
quiet queue.
"""
from __future__ import annotations

import collections
import itertools
import queue
import threading
import time
import warnings
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any

from repro.core.batch import _bucket_shape
from repro.core.kinds import get_kind
from repro.core.refill import refill_runtime
from repro.core.solver_loop import trace_cycles
from repro.launch.mesh import scheduler_lanes, shard_count
from repro.obs.trace import current_tracer
from repro.serve.engine import SolverEngine, _merge_deprecated_kw
from repro.serve.metrics import SchedulerMetrics

_SENTINEL = object()


@dataclass
class _Request:
    ticket: int
    kind: str
    payload: Any
    future: Future
    submit_t: float
    deadline_t: float
    queued_t: float = 0.0     # enqueue timestamp (queue-wait span start)
    warm: Any = None          # WarmStart seed (submit(base=/delta=)) or None


@dataclass
class _Lane:
    """One dispatch lane: its own SolverEngine (sub-mesh) + worker thread."""
    engine: SolverEngine
    work: "queue.Queue[Any]" = field(
        default_factory=lambda: queue.Queue(maxsize=1))
    thread: threading.Thread | None = None


def choose_driver(spread_ewma: float | None, n_real: int, *,
                  threshold: float, min_batch: int,
                  forced: str = "adaptive") -> bool:
    """Masked or compacted for the next dispatch? Returns ``compact``.

    ``forced`` short-circuits (``"masked"`` / ``"compacted"`` — the
    override knob). Adaptively, compaction is chosen once the observed
    convergence-spread EWMA clears ``threshold`` AND the bucket is big
    enough to amortize the host-driven gather/scatter loop
    (``min_batch``); with no history yet (EWMA ``None``) the masked
    single-dispatch driver is the safe default.
    """
    if forced == "masked":
        return False
    if forced == "compacted":
        return True
    if forced != "adaptive":
        raise ValueError(
            f"dispatch must be 'adaptive' | 'masked' | 'compacted', "
            f"got {forced!r}")
    return (spread_ewma is not None and spread_ewma > threshold
            and n_real >= min_batch)


def _refill_groups(rt, bucket: str, reqs: list) -> list[tuple[tuple, list]]:
    """Group a popped batch by session bucket shape.

    The continuous-batching analogue of the kind's ``prepare_buckets``
    policy: one refill session per bucket shape (``"max"`` → one session
    at the componentwise max; ``"pow2"`` / ``"exact"`` → one per rounded /
    exact shape), so every instance a session ever holds shares one
    compiled cycle ladder.
    """
    shapes = [rt.shape_of(r.payload) for r in reqs]
    max_shape = tuple(max(s[d] for s in shapes)
                      for d in range(len(shapes[0])))
    groups: dict[tuple, list] = {}
    for r, s in zip(reqs, shapes):
        groups.setdefault(_bucket_shape(s, bucket, max_shape), []).append(r)
    return list(groups.items())


class AsyncSolverEngine:
    """Background-flush solver serving: submit from any thread, get futures.

    Args:
      max_batch: size trigger — a kind flushes as soon as ``max_batch`` of
        its requests are queued (also the per-dispatch batch cap, so one
        flush of a long queue becomes several max-occupancy batches).
      max_delay_ms: default deadline budget — a request never waits longer
        than this for batch-mates before its kind is flushed
        (per-request ``deadline_ms`` overrides).
      dispatch: ``"adaptive"`` (default) picks masked vs compacted per
        dispatch from the convergence-spread EWMA; ``"masked"`` /
        ``"compacted"`` force one driver (the override knob).
      spread_threshold / min_compact_batch / ewma_alpha: adaptive-policy
        tuning — see ``choose_driver`` / ``repro.serve.metrics``.
      refill: continuous batching (default off). A flushed batch of a
        kind with a registered refill runtime (``SolverKind.refill``)
        becomes a ``repro.core.refill.RefillSolver`` session: slots freed
        by converged instances are refilled MID-SOLVE from the kind's
        pending queue (requests must fit the session's bucket shape), and
        futures resolve per instance as each converges. Results stay
        bit-identical to the closed-batch path (tests/test_refill.py);
        kinds without a refill runtime serve closed-batch as before.
      n_lanes: dispatch lanes for the host/device pipeline (2 =
        double-buffered). On a mesh with >= n_lanes devices each lane owns
        a disjoint sub-mesh (``repro.launch.mesh.scheduler_lanes``).
      mesh / mesh_axis / bucket / solver_kw: forwarded to the per-lane
        ``SolverEngine`` cores (same semantics as the blocking engine;
        docs/batching.md) — ``solver_kw`` is keyed by kind name.
      maxflow_kw / assignment_kw: DEPRECATED — folded into ``solver_kw``
        with a ``DeprecationWarning``.
      metrics: optional ``SchedulerMetrics`` to record into (one is
        created otherwise; read it via ``.metrics.snapshot()``).
      tracer: optional ``repro.obs.Tracer`` recording per-ticket
        lifecycle spans (``submit`` → ``queue-wait`` → ``bucket/pad`` →
        ``device-solve`` → ``refill-admission`` → ``resolve``, every span
        tagged ``ticket``/``kind``). Defaults to the AMBIENT tracer at
        construction (``repro.obs.use_tracer``) — captured once here and
        handed to the lane engines, because contextvars do not cross into
        the scheduler/lane threads. ``None`` traces nothing; the hot path
        then pays one ``None`` check per stage.

    Results are bit-identical to ``SolverEngine.flush()`` of the same
    request stream chunked the same way — and, transitively, to a loop of
    single solves (tests/test_scheduler.py).
    """

    def __init__(self, *, max_batch: int = 16, max_delay_ms: float = 50.0,
                 dispatch: str = "adaptive", spread_threshold: float = 0.25,
                 min_compact_batch: int = 4, ewma_alpha: float = 0.25,
                 refill: bool = False,
                 n_lanes: int = 2, mesh=None, mesh_axis: str | None = None,
                 bucket: str = "max",
                 solver_kw: dict[str, dict] | None = None,
                 maxflow_kw: dict | None = None,
                 assignment_kw: dict | None = None,
                 metrics: SchedulerMetrics | None = None,
                 tracer=None, cache=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_delay_ms <= 0:
            raise ValueError(
                f"max_delay_ms must be > 0, got {max_delay_ms}")
        choose_driver(None, 0, threshold=spread_threshold,
                      min_batch=min_compact_batch, forced=dispatch)
        self.max_batch = max_batch
        self.max_delay_ms = max_delay_ms
        self.dispatch = dispatch
        self.spread_threshold = spread_threshold
        self.min_compact_batch = min_compact_batch
        self.metrics = metrics or SchedulerMetrics(ewma_alpha=ewma_alpha)
        self.refill = bool(refill)
        self._bucket = bucket
        self.tracer = tracer if tracer is not None else current_tracer()

        solver_kw = _merge_deprecated_kw(
            solver_kw, maxflow_kw, assignment_kw, "AsyncSolverEngine")
        self._solver_kw = solver_kw
        # ONE solution cache shared across every lane engine — warm
        # submissions must find solutions regardless of which lane solved
        # the base request (SolutionCache is thread-safe)
        from repro.core.warm import SolutionCache
        self._cache = cache if cache is not None else SolutionCache()
        # scheduler ticket -> (kind, cache key) of its cached solution
        self._key_of_ticket: dict[int, tuple[str, str]] = {}
        # kind -> RefillRuntime | None (None = closed-batch only), lazy
        self._refill_rts: dict[str, Any] = {}
        self._lanes = [
            _Lane(engine=SolverEngine(
                mesh=lane_mesh, mesh_axis=mesh_axis, bucket=bucket,
                solver_kw=solver_kw, tracer=self.tracer,
                cache=self._cache))
            for lane_mesh in scheduler_lanes(mesh, mesh_axis, n_lanes)]
        self._rr = itertools.cycle(range(len(self._lanes)))

        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        # per-kind FIFO queues, keyed lazily by the kinds that actually
        # arrive (insertion order fixes the flush order across kinds)
        self._pending: dict[str, collections.deque[_Request]] = {}
        self._next_ticket = 0
        self._manual = False
        self._closing = False
        self._closed = False

        self._scheduler = threading.Thread(
            target=self._scheduler_loop, name="solver-scheduler",
            daemon=True)
        self._scheduler.start()
        for i, lane in enumerate(self._lanes):
            lane.thread = threading.Thread(
                target=self._lane_loop, args=(lane,),
                name=f"solver-lane-{i}", daemon=True)
            lane.thread.start()

    # ---- submission ------------------------------------------------------

    def _resolve_base(self, kind: str, base):
        """``submit(base=)`` -> ``(base_problem, solution)`` or ``KeyError``.

        ``base`` is a prior ticket of THIS scheduler (int) or a
        ``SolutionCache`` content key (str); the lookup hit/miss is
        recorded (``warm`` metrics key).
        """
        if isinstance(base, int):
            with self._lock:
                mapped = self._key_of_ticket.get(base)
            if mapped is None or mapped[0] != kind:
                self.metrics.record_cache_lookup(False)
                raise KeyError(
                    f"base ticket {base} has no cached {kind!r} solution "
                    f"(unsolved, evicted, or a different kind)")
            base = mapped[1]
        hit = self._cache.get(base)
        self.metrics.record_cache_lookup(hit is not None)
        if hit is None:
            raise KeyError(
                f"no cached solution under key {base!r} (evicted?)")
        return hit.problem, hit.solution

    def _cache_result(self, kind: str, req: "_Request", res) -> None:
        """Cache a resolved request's solution so its ticket can seed a
        later ``submit(base=ticket)`` (kinds with a ``solution_of`` hook)."""
        k = get_kind(kind)
        if res is None or k.solution_of is None:
            return
        key = self._cache.put(kind, req.payload, k.solution_of(res))
        with self._lock:
            self._key_of_ticket[req.ticket] = (kind, key)

    def submit(self, kind: str, payload=None, *,
               deadline_ms: float | None = None,
               base=None, delta=None) -> Future:
        """Queue one request of a registered kind; returns a Future.

        Validation happens HERE, synchronously, via the kind's registered
        validator — a rejected payload (or an unknown kind) raises
        ``ValueError`` and no future is created. ``future.result()`` is
        the same result the blocking engine's ``flush`` would return for
        this request.

        Incremental re-solve (docs/warmstart.md): ``base=`` — a prior
        ticket of this scheduler or a ``SolutionCache`` key — warm-starts
        from that solved instance; ``delta`` (a ``GraphDelta`` or
        sequence) derives the new payload from the base problem when
        ``payload`` is ``None``. A ``base`` with no cached solution
        raises ``KeyError`` synchronously (retry with a cold submit).
        Warm requests batch, refill, and fail-isolate exactly like cold
        ones; they reach the same optima (tests/test_warm.py).
        """
        t0 = time.monotonic()
        ws = None
        if base is not None:
            from repro.core.warm import WarmStart, apply_delta
            bp, solution = self._resolve_base(kind, base)
            if payload is None:
                if delta is None:
                    raise ValueError(
                        "submit(base=...) needs a payload or a delta to "
                        "derive one")
                payload = apply_delta(kind, bp, delta)
            elif delta is not None:
                payload = apply_delta(kind, payload, delta)
            ws = WarmStart(solution, base_problem=bp)
        elif delta is not None:
            raise ValueError("submit(delta=...) needs base= to apply it to")
        elif payload is None:
            raise ValueError("submit() needs a payload (or base=/delta=)")
        payload = get_kind(kind).validate(payload)
        now = time.monotonic()
        budget = self.max_delay_ms if deadline_ms is None else deadline_ms
        if budget <= 0:
            raise ValueError(f"deadline_ms must be > 0, got {deadline_ms}")
        fut: Future = Future()
        with self._cond:
            if self._closing:
                raise RuntimeError(
                    "AsyncSolverEngine is closed; no new submissions")
            req = _Request(ticket=self._next_ticket, kind=kind,
                           payload=payload, future=fut, submit_t=now,
                           deadline_t=now + budget / 1e3,
                           queued_t=time.monotonic(), warm=ws)
            self._next_ticket += 1
            self._pending.setdefault(kind, collections.deque()).append(req)
            self.metrics.record_submit(self._depth_locked())
            self._cond.notify_all()
        if self.tracer is not None:
            # submit ends exactly where queue-wait begins (queued_t), so a
            # ticket's lifecycle spans chain without gaps or overlaps
            self.tracer.record("submit", t0, req.queued_t,
                               ticket=req.ticket, kind=kind,
                               init="warm" if ws is not None else "cold")
        return fut

    def submit_maxflow(self, problem, *,
                       deadline_ms: float | None = None) -> Future:
        """DEPRECATED: use ``submit("maxflow", problem)``."""
        warnings.warn(
            'submit_maxflow(...) is deprecated; use submit("maxflow", ...)',
            DeprecationWarning, stacklevel=2)
        return self.submit("maxflow", problem, deadline_ms=deadline_ms)

    def submit_assignment(self, w, *,
                          deadline_ms: float | None = None) -> Future:
        """DEPRECATED: use ``submit("assignment", w)``."""
        warnings.warn(
            'submit_assignment(...) is deprecated; use '
            'submit("assignment", ...)', DeprecationWarning, stacklevel=2)
        return self.submit("assignment", w, deadline_ms=deadline_ms)

    def flush_now(self) -> None:
        """Manual trigger: flush everything pending without waiting.

        A no-op on an empty queue — the flag must not stay armed, or the
        NEXT lone submission would dispatch as a singleton batch instead
        of waiting for batch-mates.
        """
        with self._cond:
            if self._depth_locked() > 0:
                self._manual = True
                self._cond.notify_all()

    def pending(self) -> int:
        """Requests queued but not yet handed to a dispatch lane."""
        with self._lock:
            return self._depth_locked()

    def _depth_locked(self) -> int:
        return sum(len(q) for q in self._pending.values())

    # ---- scheduler thread: triggers + the host half of the pipeline -----

    def _next_deadline_locked(self) -> float | None:
        ds = [r.deadline_t for q in self._pending.values() for r in q]
        return min(ds) if ds else None

    def _trigger_ready_locked(self, now: float) -> bool:
        if self._manual or self._closing:
            return self._depth_locked() > 0
        if any(len(q) >= self.max_batch for q in self._pending.values()):
            return True
        nd = self._next_deadline_locked()
        return nd is not None and nd <= now

    def _pop_batches_locked(self, now: float) -> list[tuple]:
        """Pop every batch whose trigger fired: ``(kind, reqs, trigger)``.

        Size triggers pop exactly ``max_batch`` oldest requests (FIFO =
        ticket order); a deadline/manual/drain trigger flushes the whole
        kind in ``max_batch``-sized chunks so one expired request cannot
        strand its batch-mates.
        """
        batches = []
        for kind in list(self._pending):
            q = self._pending[kind]
            while len(q) >= self.max_batch:
                batches.append((kind, [q.popleft()
                                       for _ in range(self.max_batch)],
                                "size"))
            if q and (self._closing or self._manual
                      or min(r.deadline_t for r in q) <= now):
                trigger = ("drain" if self._closing else
                           "manual" if self._manual else "deadline")
                while q:
                    chunk = [q.popleft()
                             for _ in range(min(self.max_batch, len(q)))]
                    batches.append((kind, chunk, trigger))
        self._manual = False
        return batches

    def _scheduler_loop(self) -> None:
        while True:
            with self._cond:
                now = time.monotonic()
                while not self._trigger_ready_locked(now):
                    if self._closing:      # closing + nothing pending: done
                        return
                    nd = self._next_deadline_locked()
                    self._cond.wait(
                        timeout=None if nd is None else max(nd - now, 0.0))
                    now = time.monotonic()
                batches = self._pop_batches_locked(now)
                depth = self._depth_locked()
            t_pop = time.monotonic()
            for kind, reqs, trigger in batches:
                self.metrics.record_flush(trigger, depth)
                # drop requests whose future the caller already cancelled
                live = [r for r in reqs
                        if r.future.set_running_or_notify_cancel()]
                self.metrics.record_cancelled(len(reqs) - len(live))
                if not live:
                    continue
                if self.tracer is not None:
                    for r in live:
                        self.tracer.record("queue-wait", r.queued_t, t_pop,
                                           ticket=r.ticket, kind=kind,
                                           trigger=trigger)
                rt = self._refill_rt(kind) if self.refill else None
                if rt is not None:
                    # continuous batching: one session per bucket shape,
                    # admission happens inside the lane at cycle boundaries
                    # (warm seeds/admissions ride through the session's
                    # warm= / (payload, WarmStart) forms)
                    for bshape, group in _refill_groups(
                            rt, self._bucket, live):
                        lane = self._lanes[next(self._rr)]
                        lane.work.put(("refill", kind, group, bshape))
                    continue
                if any(r.warm is not None for r in live):
                    # warm-seeded batches build per-instance states, so
                    # they skip the shared prepare stage and route whole
                    # through the warm seam (repro.core.warm.solve_warm)
                    lane = self._lanes[next(self._rr)]
                    lane.work.put(("warm", kind, live, None))
                    continue
                lane = self._lanes[next(self._rr)]
                try:
                    # HOST stage: pad-and-bucket (overlaps the device solve
                    # of whatever this lane is already running)
                    preps = lane.engine.prepare(
                        kind, [r.payload for r in live])
                except Exception as e:        # can't prepare: fail the batch
                    for r in live:
                        r.future.set_exception(e)
                        self.metrics.record_done(0.0, ok=False)
                    continue
                # blocks when the lane already holds a staged batch —
                # bounded hand-off, one staged + one in-flight per lane
                lane.work.put(("batch", kind, live, preps))

    # ---- lane threads: the device half of the pipeline -------------------

    def _lane_loop(self, lane: _Lane) -> None:
        while True:
            item = lane.work.get()
            if item is _SENTINEL:
                return
            tag, kind, reqs, extra = item
            try:
                if tag == "refill":
                    # extra = bucket shape; reqs GROWS in place as the
                    # session admits, so the fallback below covers every
                    # request the session ever owned
                    self._solve_refill(lane, kind, reqs, extra)
                elif tag == "warm":
                    self._solve_warm_batch(lane, kind, reqs)
                else:
                    self._solve_batch(lane, kind, reqs, extra)
            except Exception:
                try:
                    self._isolate_failures(lane, kind, reqs)
                except Exception as e:
                    # last resort: the lane thread must survive and every
                    # future must resolve, or shutdown could hang
                    for r in reqs:
                        if not r.future.done():
                            self.metrics.record_done(0.0, ok=False)
                            r.future.set_exception(e)

    def _solve_batch(self, lane: _Lane, kind: str, reqs: list[_Request],
                     preps: list) -> None:
        results: dict[int, Any] = {}
        for prep in preps:
            compact = choose_driver(
                self.metrics.convergence.spread(kind),
                len(prep.idxs), threshold=self.spread_threshold,
                min_batch=self.min_compact_batch, forced=self.dispatch)
            t_disp = time.monotonic()
            with trace_cycles(self.metrics.record_live_trace):
                out, stats = lane.engine.solve_prepared(
                    prep, compact=compact)
            if self.tracer is not None:
                # per-ticket view of the bucket dispatch (the engine also
                # records the aggregate device-solve span)
                t_end = time.monotonic()
                for i in prep.idxs:
                    self.tracer.record(
                        "solve", t_disp, t_end, ticket=reqs[i].ticket,
                        kind=kind, bucket=list(prep.shape),
                        driver="compacted" if compact else "masked",
                        init="cold")
            self.metrics.record_dispatch(
                kind, compact=compact, spread=stats.spread,
                occupancy=stats.n_real / self.max_batch,
                rounds=stats.rounds_mean, heuristics=stats.heur_mean)
            results.update(out)
        # cold solves count into the warm-fraction denominator too
        self.metrics.record_warm(kind, 0, len(reqs))
        now = time.monotonic()
        for i, r in enumerate(reqs):
            self._cache_result(kind, r, results[i])
            # metrics BEFORE resolution: a caller waiting on result() may
            # read snapshot() the instant the future resolves
            self.metrics.record_done((now - r.submit_t) * 1e3)
            if self.tracer is None:
                r.future.set_result(results[i])
            else:
                tr0 = time.monotonic()
                r.future.set_result(results[i])
                self.tracer.record("resolve", tr0, time.monotonic(),
                                   ticket=r.ticket, kind=kind)

    def _solve_warm_batch(self, lane: _Lane, kind: str,
                          reqs: list[_Request]) -> None:
        """One warm-seeded (possibly mixed warm/cold) closed batch.

        Routes through ``SolverEngine.solve_requests(warm=)`` — the
        per-instance warm/cold init seam — instead of the two-stage
        prepare/solve pipeline. Warm instances' rounds are kept OUT of the
        kind's cold-rounds EWMA (they would drag the baseline down and
        corrupt the rounds-saved signal); the dispatch is recorded with
        ``rounds=None`` and the warm composition goes through
        ``record_warm`` instead.
        """
        warm = {i: r.warm for i, r in enumerate(reqs) if r.warm is not None}
        compact = choose_driver(
            self.metrics.convergence.spread(kind), len(reqs),
            threshold=self.spread_threshold,
            min_batch=self.min_compact_batch, forced=self.dispatch)
        stats_out: list = []
        t_disp = time.monotonic()
        results = lane.engine.solve_requests(
            kind, [r.payload for r in reqs], compact=compact,
            stats_out=stats_out, warm=warm)
        t_end = time.monotonic()
        for stats in stats_out:
            self.metrics.record_dispatch(
                kind, compact=stats.compact, spread=stats.spread,
                occupancy=stats.n_real / self.max_batch, rounds=None)
        cold_ewma = self.metrics.convergence.rounds(kind)
        warm_rounds = [float(results[i].rounds) for i in warm
                       if results[i] is not None
                       and getattr(results[i], "rounds", None) is not None]
        rounds_saved = (cold_ewma - sum(warm_rounds) / len(warm_rounds)
                        if cold_ewma is not None and warm_rounds else None)
        self.metrics.record_warm(kind, len(warm), len(reqs) - len(warm),
                                 rounds_saved)
        now = time.monotonic()
        for i, r in enumerate(reqs):
            self._cache_result(kind, r, results[i])
            self.metrics.record_done((now - r.submit_t) * 1e3)
            if self.tracer is None:
                r.future.set_result(results[i])
            else:
                self.tracer.record(
                    "solve", t_disp, t_end, ticket=r.ticket, kind=kind,
                    driver="compacted" if compact else "masked",
                    init="warm" if i in warm else "cold")
                tr0 = time.monotonic()
                r.future.set_result(results[i])
                self.tracer.record("resolve", tr0, time.monotonic(),
                                   ticket=r.ticket, kind=kind)

    def _refill_rt(self, kind: str):
        """The kind's refill runtime, or ``None`` if it serves closed-batch
        only (cached per kind — runtimes are stateless)."""
        if kind not in self._refill_rts:
            try:
                self._refill_rts[kind] = refill_runtime(
                    kind, **self._solver_kw.get(kind, {}))
            except ValueError:
                self._refill_rts[kind] = None
        return self._refill_rts[kind]

    def _pop_refill(self, kind: str, solver, n: int) -> list[_Request]:
        """Pop up to ``n`` pending requests of ``kind`` that fit ``solver``'s
        session bucket, preserving FIFO order of the rest."""
        with self._cond:
            q = self._pending.get(kind)
            if not q:
                return []
            taken: list[_Request] = []
            keep: list[_Request] = []
            for r in q:
                if len(taken) < n and solver.fits(r.payload):
                    taken.append(r)
                else:
                    keep.append(r)
            if taken:
                q.clear()
                q.extend(keep)
            return taken

    def _solve_refill(self, lane: _Lane, kind: str, reqs: list[_Request],
                      bshape: tuple) -> None:
        """One continuous-batching session on ``lane`` (``refill=True``).

        ``reqs`` seed the session; at every cycle boundary the session's
        ``admit`` callback pops fitting pending requests of the same kind
        (appending them to ``reqs`` — the list index IS the session request
        index), and each future resolves through ``on_result`` the moment
        its instance converges.  Capacity is ``max_batch`` rounded up to a
        multiple of the lane's shard count so the slot array splits evenly
        across its sub-mesh.  If the session itself aborts, the lane loop's
        poison-isolation fallback re-solves every unresolved request solo.
        """
        mesh = lane.engine.mesh
        sc = 1 if mesh is None else shard_count(mesh, lane.engine.mesh_axis)
        cap = -(-self.max_batch // sc) * sc
        solver = lane.engine.refill_session(kind, shape=bshape, capacity=cap)
        self.metrics.record_refill_session(kind)
        # per-request solve-span starts: seeds start with the session, an
        # admitted request the moment its admission lands
        t_session = time.monotonic()
        solve_t0 = {i: t_session for i in range(len(reqs))}

        def admit_cb(n_free: int) -> list:
            t_adm = time.monotonic()
            taken = self._pop_refill(kind, solver, n_free)
            live = [r for r in taken
                    if r.future.set_running_or_notify_cancel()]
            self.metrics.record_cancelled(len(taken) - len(live))
            if live:
                self.metrics.record_refill_admit(kind, len(live))
                base = len(reqs)
                reqs.extend(live)
                if self.tracer is not None:
                    t_end = time.monotonic()
                    for j, r in enumerate(live):
                        solve_t0[base + j] = t_end
                        self.tracer.record("queue-wait", r.queued_t, t_adm,
                                           ticket=r.ticket, kind=kind,
                                           trigger="refill")
                    self.tracer.record(
                        "refill-admission", t_adm, t_end, kind=kind,
                        n_free=n_free, admitted=len(live),
                        tickets=[r.ticket for r in live])
                else:
                    for j in range(len(live)):
                        solve_t0[base + j] = t_adm
            return [r.payload if r.warm is None else (r.payload, r.warm)
                    for r in live]

        def on_result(idx: int, res) -> None:
            r = reqs[idx]
            self._cache_result(kind, r, res)
            now = time.monotonic()
            self.metrics.record_done((now - r.submit_t) * 1e3)
            if self.tracer is None:
                r.future.set_result(res)
            else:
                self.tracer.record("solve", solve_t0.get(idx, t_session),
                                   now, ticket=r.ticket, kind=kind,
                                   bucket=list(bshape), driver="refill",
                                   init="warm" if r.warm is not None
                                   else "cold")
                tr0 = time.monotonic()
                r.future.set_result(res)
                self.tracer.record("resolve", tr0, time.monotonic(),
                                   ticket=r.ticket, kind=kind)

        def on_error(idx: int, e: Exception) -> None:
            r = reqs[idx]
            self.metrics.record_done(0.0, ok=False)
            r.future.set_exception(e)

        def trace(cycle: int, n_live: int) -> None:
            self.metrics.record_live_trace(cycle, n_live)
            self.metrics.record_refill_cycle(kind, n_live / cap)

        seeds = [r.payload for r in list(reqs)]
        warm_seed = {i: r.warm for i, r in enumerate(reqs)
                     if r.warm is not None}
        with trace_cycles(trace):
            solver.run(seeds, admit=admit_cb, on_result=on_result,
                       on_error=on_error, warm=warm_seed or None)
        n_warm = sum(1 for r in reqs if r.warm is not None)
        if reqs:
            self.metrics.record_warm(kind, n_warm, len(reqs) - n_warm)

    def _isolate_failures(self, lane: _Lane, kind: str,
                          reqs: list[_Request]) -> None:
        """Batched dispatch failed: re-solve one request at a time.

        A poisoned request must fail ONLY its own future — everything else
        in its batch still gets a result (solved solo through the same
        tested path, so values are unchanged; only dispatch granularity
        differs).
        """
        for r in reqs:
            if r.future.done():          # already resolved before the raise
                continue
            t0 = time.monotonic()
            try:
                [res] = lane.engine.solve_requests(
                    kind, [r.payload],
                    warm={0: r.warm} if r.warm is not None else None)
            except Exception as e:
                self.metrics.record_done(0.0, ok=False)
                r.future.set_exception(e)
            else:
                self._cache_result(kind, r, res)
                self.metrics.record_warm(
                    kind, int(r.warm is not None), int(r.warm is None))
                now = time.monotonic()
                self.metrics.record_done((now - r.submit_t) * 1e3)
                if self.tracer is None:
                    r.future.set_result(res)
                else:
                    self.tracer.record("solve", t0, now, ticket=r.ticket,
                                       kind=kind, driver="isolated")
                    tr0 = time.monotonic()
                    r.future.set_result(res)
                    self.tracer.record("resolve", tr0, time.monotonic(),
                                       ticket=r.ticket, kind=kind)

    # ---- shutdown --------------------------------------------------------

    def close(self, *, drain: bool = True) -> None:
        """Stop the scheduler. Idempotent; never hangs.

        ``drain=True`` solves everything still queued (futures resolve
        normally) before threads are joined. ``drain=False`` cancels
        queued requests' futures (``Future.cancelled()`` becomes True);
        batches already handed to a lane still complete.
        """
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._closing = True            # submit() now refuses
            if not drain:
                dropped = [r for q in self._pending.values() for r in q]
                for q in self._pending.values():
                    q.clear()
            self._cond.notify_all()
        if not drain:
            for r in dropped:
                if r.future.cancel():
                    self.metrics.record_cancelled()
        self._scheduler.join()
        for lane in self._lanes:
            lane.work.put(_SENTINEL)
        for lane in self._lanes:
            lane.thread.join()

    def __enter__(self) -> "AsyncSolverEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(drain=exc_type is None)
