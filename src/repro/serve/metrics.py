"""Serving metrics: EWMA stats registry + the scheduler's telemetry surface.

Two consumers share this module:

* the ADAPTIVE DISPATCH policy of ``repro.serve.scheduler`` — an EWMA over
  the per-bucket convergence spread (``repro.core.batch.BucketStats.
  spread``) decides masked vs compacted dispatch per kind, and
* OPERATORS — ``SchedulerMetrics.snapshot()`` exposes queue depth, batch
  occupancy, ticket-latency percentiles (p50/p99), flush-trigger counts,
  and per-driver dispatch counts as one plain dict.

Everything here is thread-safe (one lock per registry): submit paths, the
scheduler thread, and the lane threads all record concurrently. Nothing
imports jax — metrics stay importable (and testable) without touching
device state.
"""
from __future__ import annotations

import collections
import copy
import threading
from typing import Any

import numpy as np


class Ewma:
    """Exponentially-weighted moving average; ``None`` until first update.

    ``alpha`` is the weight of the NEW observation (0.25 ~= averaging over
    the last ~4 batches) — recent convergence behaviour should dominate a
    serving stream whose difficulty drifts.
    """

    def __init__(self, alpha: float = 0.25):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self._value: float | None = None

    def update(self, x: float) -> float:
        v = self._value
        self._value = float(x) if v is None else \
            self.alpha * float(x) + (1.0 - self.alpha) * v
        return self._value

    @property
    def value(self) -> float | None:
        return self._value


class LatencyWindow:
    """Ring buffer of recent ticket latencies (ms) -> p50/p99 percentiles.

    A bounded window (default: the last 1024 tickets), not a full history:
    serving percentiles should describe CURRENT behaviour, and the buffer
    must not grow with uptime.
    """

    def __init__(self, maxlen: int = 1024):
        self._buf: collections.deque[float] = collections.deque(maxlen=maxlen)

    def record(self, latency_ms: float) -> None:
        self._buf.append(float(latency_ms))

    def __len__(self) -> int:
        return len(self._buf)

    def percentiles(self, qs=(50.0, 99.0)) -> dict[str, float | None]:
        if not self._buf:
            return {f"p{q:g}": None for q in qs}
        arr = np.asarray(self._buf)
        return {f"p{q:g}": float(np.percentile(arr, q)) for q in qs}


class ConvergenceStats:
    """Per-kind EWMA registry over observed batch convergence spread.

    The adaptive-dispatch signal: ``spread`` of a bucket is
    ``(rounds_max - rounds_min) / max(rounds_max, 1)`` over its real
    instances (``BucketStats.spread``). A stream whose spread EWMA is high
    is ragged — stragglers dominate masked dispatches and early-exit
    compaction pays; a low EWMA means the batch converges together and the
    single-dispatch masked driver wins (benchmarks/RESULTS_compaction.md).
    """

    def __init__(self, alpha: float = 0.25):
        self._alpha = alpha
        self._lock = threading.Lock()
        self._spread: dict[str, Ewma] = {}
        self._occupancy: dict[str, Ewma] = {}
        self._rounds: dict[str, Ewma] = {}
        self._heuristics: dict[str, Ewma] = {}

    def observe(self, kind: str, *, spread: float,
                occupancy: float | None = None,
                rounds: float | None = None,
                heuristics: float | None = None) -> None:
        with self._lock:
            self._spread.setdefault(kind, Ewma(self._alpha)).update(spread)
            if occupancy is not None:
                self._occupancy.setdefault(
                    kind, Ewma(self._alpha)).update(occupancy)
            if rounds is not None:
                self._rounds.setdefault(kind, Ewma(self._alpha)).update(rounds)
            if heuristics is not None:
                self._heuristics.setdefault(
                    kind, Ewma(self._alpha)).update(heuristics)

    def spread(self, kind: str) -> float | None:
        with self._lock:
            e = self._spread.get(kind)
            return None if e is None else e.value

    def occupancy(self, kind: str) -> float | None:
        with self._lock:
            e = self._occupancy.get(kind)
            return None if e is None else e.value

    def rounds(self, kind: str) -> float | None:
        """EWMA of per-dispatch mean solver rounds (``rounds_mean``)."""
        with self._lock:
            e = self._rounds.get(kind)
            return None if e is None else e.value

    def heuristics(self, kind: str) -> float | None:
        """EWMA of per-dispatch mean heuristic invocations (``heur_mean``)."""
        with self._lock:
            e = self._heuristics.get(kind)
            return None if e is None else e.value

    def kinds(self) -> tuple[str, ...]:
        """Every kind observed so far (union of all stat keys)."""
        with self._lock:
            return tuple(dict.fromkeys(
                [*self._spread, *self._occupancy, *self._rounds,
                 *self._heuristics]))


class SchedulerMetrics:
    """The async scheduler's full telemetry surface (thread-safe).

    Counters: submitted / completed / failed / cancelled tickets; flushes
    by trigger (``size`` | ``deadline`` | ``manual`` | ``drain``);
    dispatches by ``(kind, driver)`` where driver is ``masked`` or
    ``compacted``. Gauges: current queue depth. Distributions: ticket
    latency (submit -> future resolution) percentiles, batch-occupancy
    EWMA (real instances / max_batch), convergence-spread EWMA, per-kind
    solver-rounds and heuristic-invocation EWMAs (``rounds_ewma`` /
    ``heuristics_ewma`` — the workload-difficulty gauges fed from
    ``BucketStats.rounds_mean``/``heur_mean``), and the compacted
    driver's live-count decay (via
    ``repro.core.solver_loop.trace_cycles``).

    Continuous batching (``refill`` snapshot key): sessions opened and
    requests admitted mid-solve per kind, a per-kind slot-occupancy EWMA
    sampled every refill cycle, and the steady-state batch utilization
    (mean live/capacity across all refill cycles).

    Warm starts (``warm`` snapshot key): solution-cache lookups (hits /
    misses / hit rate), warm-vs-cold solve counts and the warm fraction,
    and a per-kind EWMA of rounds saved per warm solve relative to the
    kind's cold-rounds baseline (``rounds_saved_ewma`` — fed by the
    scheduler and engines through ``record_warm``; see docs/warmstart.md).
    """

    def __init__(self, *, latency_window: int = 1024, ewma_alpha: float = 0.25):
        self._lock = threading.Lock()
        self.convergence = ConvergenceStats(alpha=ewma_alpha)
        self._latency = LatencyWindow(maxlen=latency_window)
        self._counts = collections.Counter()
        self._flushes = collections.Counter()
        self._dispatches = collections.Counter()
        self._queue_depth = 0
        self._compact_cycles = 0
        self._compact_live_total = 0
        self._ewma_alpha = ewma_alpha
        self._refill_sessions = collections.Counter()
        self._refill_admitted = collections.Counter()
        self._refill_cycles = 0
        self._refill_occ_total = 0.0
        self._refill_occ_ewma: dict[str, Ewma] = {}
        self._cache_lookups = collections.Counter()   # "hit" / "miss"
        self._warm_solves = collections.Counter()     # "warm" / "cold"
        self._rounds_saved_ewma: dict[str, Ewma] = {}

    # ---- recording hooks (submit path / scheduler / lanes) --------------

    def record_submit(self, queue_depth: int) -> None:
        with self._lock:
            self._counts["submitted"] += 1
            self._queue_depth = queue_depth

    def record_flush(self, trigger: str, queue_depth: int) -> None:
        with self._lock:
            self._flushes[trigger] += 1
            self._queue_depth = queue_depth

    def record_dispatch(self, kind: str, *, compact: bool, spread: float,
                        occupancy: float, rounds: float | None = None,
                        heuristics: float | None = None) -> None:
        with self._lock:
            self._dispatches[(kind, "compacted" if compact else "masked")] += 1
        self.convergence.observe(kind, spread=spread, occupancy=occupancy,
                                 rounds=rounds, heuristics=heuristics)

    def record_done(self, latency_ms: float, *, ok: bool = True) -> None:
        with self._lock:
            self._counts["completed" if ok else "failed"] += 1
            if ok:
                self._latency.record(latency_ms)

    def record_cancelled(self, n: int = 1) -> None:
        with self._lock:
            self._counts["cancelled"] += n

    def record_live_trace(self, cycle: int, n_live: int) -> None:
        """Per-cycle live-count sample from the compacted driver."""
        with self._lock:
            self._compact_cycles += 1
            self._compact_live_total += n_live

    def record_refill_session(self, kind: str) -> None:
        """One continuous-batching session opened for ``kind``."""
        with self._lock:
            self._refill_sessions[kind] += 1

    def record_refill_admit(self, kind: str, n: int) -> None:
        """``n`` queued requests admitted mid-solve into a ``kind`` session."""
        with self._lock:
            self._refill_admitted[kind] += n

    def record_refill_cycle(self, kind: str, occupancy: float) -> None:
        """Per-cycle slot occupancy (live / capacity) of a refill session.

        Feeds both the steady-state utilization mean and a per-kind EWMA —
        the continuous-batching analogue of the closed-batch occupancy
        gauge, but sampled every CYCLE rather than once per dispatch, so it
        reflects how full the batch stays between admissions.
        """
        with self._lock:
            self._refill_cycles += 1
            self._refill_occ_total += float(occupancy)
            self._refill_occ_ewma.setdefault(
                kind, Ewma(self._ewma_alpha)).update(occupancy)

    def record_cache_lookup(self, hit: bool) -> None:
        """One solution-cache lookup on the warm-start path (hit or miss)."""
        with self._lock:
            self._cache_lookups["hit" if hit else "miss"] += 1

    def record_warm(self, kind: str, n_warm: int, n_cold: int,
                    rounds_saved: float | None = None) -> None:
        """Warm/cold composition of one dispatch, plus the rounds saved.

        ``rounds_saved`` is (cold-rounds EWMA of the kind) minus (this
        dispatch's mean warm rounds) — positive when warm starts converge
        in fewer rounds than the kind's recent cold baseline. Callers feed
        it only when both sides exist; the EWMA smooths per-dispatch noise.
        """
        with self._lock:
            self._warm_solves["warm"] += int(n_warm)
            self._warm_solves["cold"] += int(n_cold)
            if rounds_saved is not None:
                self._rounds_saved_ewma.setdefault(
                    kind, Ewma(self._ewma_alpha)).update(rounds_saved)

    # ---- reading --------------------------------------------------------

    def dispatch_count(self, kind: str, driver: str) -> int:
        with self._lock:
            return self._dispatches[(kind, driver)]

    def snapshot(self) -> dict[str, Any]:
        """One coherent dict of every counter/gauge/percentile.

        Returns a DEEP COPY: mutating the returned dict (any nesting
        level) can never reach live registry state, so operators may
        post-process snapshots freely (tests/test_obs.py pins this).
        """
        with self._lock:
            snap = {
                "queue_depth": self._queue_depth,
                "tickets": dict(self._counts),
                "flushes_by_trigger": dict(self._flushes),
                "dispatches": {f"{k}:{d}": n for (k, d), n
                               in self._dispatches.items()},
                "latency_ms": self._latency.percentiles(),
                "latency_samples": len(self._latency),
                "compact_cycles": self._compact_cycles,
                "compact_live_mean": (
                    self._compact_live_total / self._compact_cycles
                    if self._compact_cycles else None),
                "refill": {
                    "sessions": dict(self._refill_sessions),
                    "admitted": dict(self._refill_admitted),
                    "slot_occupancy_ewma": {
                        k: e.value for k, e in self._refill_occ_ewma.items()},
                    "utilization": (
                        self._refill_occ_total / self._refill_cycles
                        if self._refill_cycles else None),
                },
                "warm": {
                    "cache_hits": self._cache_lookups["hit"],
                    "cache_misses": self._cache_lookups["miss"],
                    "cache_hit_rate": (
                        self._cache_lookups["hit"]
                        / sum(self._cache_lookups.values())
                        if self._cache_lookups else None),
                    "warm_solves": self._warm_solves["warm"],
                    "cold_solves": self._warm_solves["cold"],
                    "warm_fraction": (
                        self._warm_solves["warm"]
                        / sum(self._warm_solves.values())
                        if sum(self._warm_solves.values()) else None),
                    "rounds_saved_ewma": {
                        k: e.value
                        for k, e in self._rounds_saved_ewma.items()},
                },
            }
        kinds = _snapshot_kinds(self.convergence)
        snap["spread_ewma"] = {k: self.convergence.spread(k) for k in kinds}
        snap["occupancy_ewma"] = {
            k: self.convergence.occupancy(k) for k in kinds}
        snap["rounds_ewma"] = {k: self.convergence.rounds(k) for k in kinds}
        snap["heuristics_ewma"] = {
            k: self.convergence.heuristics(k) for k in kinds}
        # deepcopy is belt-and-braces over the per-field dict() copies
        # above: it guarantees the deep-isolation contract survives any
        # future field whose value nests mutable state
        return copy.deepcopy(snap)


def _snapshot_kinds(convergence: ConvergenceStats) -> tuple[str, ...]:
    """Kinds a snapshot should report EWMAs for.

    The union of the REGISTERED kinds (so a quiet kind still appears, with
    ``None`` EWMAs) and the OBSERVED kinds (so nothing recorded is ever
    hidden). The registry is peeked without importing the solver modules
    (``ensure=False``) — this module must stay importable without jax.
    """
    from repro.core.kinds import registered_kinds
    seen = dict.fromkeys(registered_kinds(ensure=False))
    seen.update(dict.fromkeys(convergence.kinds()))
    return tuple(seen)
