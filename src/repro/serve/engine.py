"""Serving: prefill + decode steps with batched requests.

``serve_step`` is what the decode_* / long_* dry-run shapes lower: one new
token for every request in the batch against a full KV/SSM cache.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Sharder
from repro.models.model import apply_model, init_caches


class ServeState(NamedTuple):
    caches: Any
    last_tokens: jax.Array    # (B,) most recent token per request
    lengths: jax.Array        # (B,) current sequence lengths


def make_prefill_step(cfg: ModelConfig, axes, cache_axes, shd: Sharder):
    def prefill(params, tokens, caches):
        """tokens: (B, S). Returns (first generated token, ServeState)."""
        out = apply_model(params, axes, cfg, shd, {"tokens": tokens},
                          caches=caches, logits_mode="last")
        nxt = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)
        B, S = tokens.shape
        return nxt, ServeState(out.caches, nxt,
                               jnp.full((B,), S, jnp.int32))
    return prefill


def make_serve_step(cfg: ModelConfig, axes, shd: Sharder,
                    pos_offset: int | None = None):
    """Decode one token for the whole batch (the dry-run `serve_step`).

    pos_offset=None reads the position from state.lengths (traced), so one
    compiled step serves every decode position.
    """
    def serve_step(params, state: ServeState):
        off = state.lengths[0] if pos_offset is None else pos_offset
        out = apply_model(params, axes, cfg, shd,
                          {"tokens": state.last_tokens[:, None]},
                          caches=state.caches, decode=True,
                          pos_offset=off, logits_mode="last")
        nxt = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, ServeState(out.caches, nxt, state.lengths + 1)
    return serve_step


def greedy_generate(cfg, params, axes, shd, prompt_tokens, max_new: int,
                    S_max: int | None = None):
    """Reference end-to-end generation loop (examples/tests)."""
    B, S = prompt_tokens.shape
    S_max = S_max or (S + max_new + 1)
    caches, _ = init_caches(cfg, B, S_max, dtype=jnp.float32)
    prefill = make_prefill_step(cfg, axes, None, shd)
    nxt, state = prefill(params, prompt_tokens, caches)
    step = make_serve_step(cfg, axes, shd)
    toks = [nxt]
    for _ in range(max_new - 1):
        nxt, state = step(params, state)
        toks.append(nxt)
    return jnp.stack(toks, axis=1)
