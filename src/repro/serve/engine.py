"""Serving: batched model decode AND the batched-solver request path.

Two engines live here:

* the LLM path — ``make_prefill_step`` / ``make_serve_step``: one new token
  for every request in the batch against a full KV/SSM cache (what the
  decode_* / long_* dry-run shapes lower), and
* the solver path — ``SolverEngine``: the ROADMAP's request-queue →
  pad-and-bucket → (mesh-sharded) batched-solve pipeline for the paper's
  flow/matching solvers. Requests of mixed kinds and ragged shapes are
  queued with ``submit_maxflow`` / ``submit_assignment`` and solved together
  on ``flush()`` — grids and cost matrices are bucketed and padded by
  ``repro.core.batch``, every bucket is one jitted dispatch, and an optional
  device mesh shards each bucket's batch axis (``shard_map``, zero
  cross-device traffic; see docs/batching.md).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import Sharder
from repro.models.model import apply_model, init_caches


class ServeState(NamedTuple):
    caches: Any
    last_tokens: jax.Array    # (B,) most recent token per request
    lengths: jax.Array        # (B,) current sequence lengths


def make_prefill_step(cfg: ModelConfig, axes, cache_axes, shd: Sharder):
    def prefill(params, tokens, caches):
        """tokens: (B, S). Returns (first generated token, ServeState)."""
        out = apply_model(params, axes, cfg, shd, {"tokens": tokens},
                          caches=caches, logits_mode="last")
        nxt = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)
        B, S = tokens.shape
        return nxt, ServeState(out.caches, nxt,
                               jnp.full((B,), S, jnp.int32))
    return prefill


def make_serve_step(cfg: ModelConfig, axes, shd: Sharder,
                    pos_offset: int | None = None):
    """Decode one token for the whole batch (the dry-run `serve_step`).

    pos_offset=None reads the position from state.lengths (traced), so one
    compiled step serves every decode position.
    """
    def serve_step(params, state: ServeState):
        off = state.lengths[0] if pos_offset is None else pos_offset
        out = apply_model(params, axes, cfg, shd,
                          {"tokens": state.last_tokens[:, None]},
                          caches=state.caches, decode=True,
                          pos_offset=off, logits_mode="last")
        nxt = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, ServeState(out.caches, nxt, state.lengths + 1)
    return serve_step


class SolverEngine:
    """Request queue -> pad-and-bucket -> (sharded) batched solve.

    The serving front door for the paper's two solvers. Callers ``submit_*``
    problems as they arrive and receive integer tickets; ``flush()`` solves
    everything pending — max-flow requests through
    ``repro.core.batch.solve_maxflow_batch`` and assignment requests through
    ``solve_assignment_batch`` — and returns ``{ticket: result}``. Results
    are exactly what the direct front-end calls would return (same padding,
    same bucketing, bit-identical values), so correctness is inherited from
    the tested batch path.

    Args:
      mesh / mesh_axis: optional ``jax.sharding.Mesh``
        (``repro.launch.mesh.make_solver_mesh``) — each bucket's batch axis
        is sharded across the mesh; ragged bucket sizes are padded with
        inert instances automatically.
      bucket: bucketing policy for ragged queues (``"max"`` | ``"pow2"`` |
        ``"exact"``, see docs/batching.md).
      compact: early-exit compaction of each bucket's batch (the
        ``compact=`` knob of ``repro.core.batch`` / the solvers): requests
        that converge early are dropped from the working set between cycle
        segments instead of being select-masked until the bucket's slowest
        request finishes. Off by default; worth opting into for serving
        queues, whose convergence is naturally ragged (see
        benchmarks/RESULTS_compaction.md). Results stay bit-identical.
      maxflow_kw / assignment_kw: per-kind solver keyword overrides
        (``backend=``, ``method=``, ``max_rounds=``, ...).
    """

    def __init__(self, *, mesh=None, mesh_axis: str | None = None,
                 bucket: str = "max", compact: bool = False,
                 maxflow_kw: dict | None = None,
                 assignment_kw: dict | None = None):
        self.mesh, self.mesh_axis, self.bucket = mesh, mesh_axis, bucket
        self.compact = compact
        self.maxflow_kw = dict(maxflow_kw or {})
        self.assignment_kw = dict(assignment_kw or {})
        self._next_ticket = 0
        self._maxflow: list[tuple[int, Any]] = []
        self._assignment: list[tuple[int, Any]] = []

    def _ticket(self) -> int:
        t, self._next_ticket = self._next_ticket, self._next_ticket + 1
        return t

    def submit_maxflow(self, problem) -> int:
        """Queue a ``GridProblem`` (any (H, W)); returns its ticket.

        Malformed requests are rejected HERE (before a ticket is issued) so
        ``flush`` cannot be wedged by a bad queue entry.
        """
        cap, cs, ct = (jnp.asarray(a) for a in problem)
        if cap.ndim != 3 or cap.shape[0] != 4 or cs.shape != ct.shape \
                or cs.shape != cap.shape[1:]:
            raise ValueError(
                f"malformed grid problem: cap_nbr {cap.shape}, "
                f"cap_src {cs.shape}, cap_sink {ct.shape}; expected "
                f"(4, H, W) / (H, W) / (H, W)")
        t = self._ticket()
        self._maxflow.append((t, problem))
        return t

    def submit_assignment(self, w) -> int:
        """Queue a square integer weight matrix (any n); returns its ticket.

        Rejects non-square or non-integer matrices at submit time.
        """
        w = np.asarray(w)
        if w.ndim != 2 or w.shape[0] != w.shape[1] \
                or not np.issubdtype(w.dtype, np.integer):
            raise ValueError(
                f"malformed assignment request: need a square integer "
                f"matrix, got shape {w.shape} dtype {w.dtype}")
        t = self._ticket()
        self._assignment.append((t, w))
        return t

    def pending(self) -> int:
        """Number of queued, unsolved requests."""
        return len(self._maxflow) + len(self._assignment)

    def flush(self) -> dict[int, Any]:
        """Solve every pending request; returns ``{ticket: result}``.

        One batched dispatch per (kind, bucket shape); the queue is emptied
        even if a request did not converge (check ``result.converged``).
        """
        from repro.core.batch import (solve_assignment_batch,
                                      solve_maxflow_batch)
        out: dict[int, Any] = {}
        if self._maxflow:
            tickets, probs = zip(*self._maxflow)
            res = solve_maxflow_batch(
                list(probs), bucket=self.bucket, compact=self.compact,
                mesh=self.mesh, mesh_axis=self.mesh_axis, **self.maxflow_kw)
            out.update(zip(tickets, res))
        if self._assignment:
            tickets, ws = zip(*self._assignment)
            res = solve_assignment_batch(
                list(ws), bucket=self.bucket, compact=self.compact,
                mesh=self.mesh, mesh_axis=self.mesh_axis,
                **self.assignment_kw)
            out.update(zip(tickets, res))
        # clear only after BOTH kinds solved: a raise above (e.g. a malformed
        # request) leaves the queues intact so no ticket is silently dropped
        self._maxflow.clear()
        self._assignment.clear()
        return out


def greedy_generate(cfg, params, axes, shd, prompt_tokens, max_new: int,
                    S_max: int | None = None):
    """Reference end-to-end generation loop (examples/tests)."""
    B, S = prompt_tokens.shape
    S_max = S_max or (S + max_new + 1)
    caches, _ = init_caches(cfg, B, S_max, dtype=jnp.float32)
    prefill = make_prefill_step(cfg, axes, None, shd)
    nxt, state = prefill(params, prompt_tokens, caches)
    step = make_serve_step(cfg, axes, shd)
    toks = [nxt]
    for _ in range(max_new - 1):
        nxt, state = step(params, state)
        toks.append(nxt)
    return jnp.stack(toks, axis=1)
