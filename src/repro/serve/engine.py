"""Serving: batched model decode AND the batched-solver request path.

Two engines live here:

* the LLM path — ``make_prefill_step`` / ``make_serve_step``: one new token
  for every request in the batch against a full KV/SSM cache (what the
  decode_* / long_* dry-run shapes lower), and
* the solver path — ``SolverEngine``: the ROADMAP's request-queue →
  pad-and-bucket → (mesh-sharded) batched-solve pipeline for the paper's
  flow/matching solvers. Requests of mixed kinds and ragged shapes are
  queued with ``submit_maxflow`` / ``submit_assignment`` and solved together
  on ``flush()`` — grids and cost matrices are bucketed and padded by
  ``repro.core.batch``, every bucket is one jitted dispatch, and an optional
  device mesh shards each bucket's batch axis (``shard_map``, zero
  cross-device traffic; see docs/batching.md).

``SolverEngine`` is also the SYNCHRONOUS CORE of the async serving
scheduler (``repro.serve.scheduler.AsyncSolverEngine``): the scheduler
drives the engine's two-stage ``prepare`` (host pad-and-bucket) /
``solve_prepared`` (device dispatch) split so batch *k+1*'s host work
overlaps batch *k*'s device solve — see docs/serving.md.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batch import (BucketStats, PreparedBucket,
                              prepare_assignment_buckets,
                              prepare_maxflow_buckets,
                              solve_prepared_assignment,
                              solve_prepared_maxflow)
from repro.core.maxflow.grid import GridProblem
from repro.models.layers import Sharder
from repro.models.model import apply_model, init_caches


class ServeState(NamedTuple):
    caches: Any
    last_tokens: jax.Array    # (B,) most recent token per request
    lengths: jax.Array        # (B,) current sequence lengths


def make_prefill_step(cfg: ModelConfig, axes, cache_axes, shd: Sharder):
    def prefill(params, tokens, caches):
        """tokens: (B, S). Returns (first generated token, ServeState)."""
        out = apply_model(params, axes, cfg, shd, {"tokens": tokens},
                          caches=caches, logits_mode="last")
        nxt = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)
        B, S = tokens.shape
        return nxt, ServeState(out.caches, nxt,
                               jnp.full((B,), S, jnp.int32))
    return prefill


def make_serve_step(cfg: ModelConfig, axes, shd: Sharder,
                    pos_offset: int | None = None):
    """Decode one token for the whole batch (the dry-run `serve_step`).

    pos_offset=None reads the position from state.lengths (traced), so one
    compiled step serves every decode position.
    """
    def serve_step(params, state: ServeState):
        off = state.lengths[0] if pos_offset is None else pos_offset
        out = apply_model(params, axes, cfg, shd,
                          {"tokens": state.last_tokens[:, None]},
                          caches=state.caches, decode=True,
                          pos_offset=off, logits_mode="last")
        nxt = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, ServeState(out.caches, nxt, state.lengths + 1)
    return serve_step


def validate_grid_problem(problem) -> GridProblem:
    """Canonicalize + validate a max-flow request (shapes, dtypes, values).

    The submit-time contract shared by ``SolverEngine`` and
    ``AsyncSolverEngine``: malformed requests are rejected BEFORE a ticket
    or future exists, so a queue can never hold an entry that would wedge a
    batched flush. Checks shape ((4, H, W) / (H, W) / (H, W)), numeric
    dtype (bool and object arrays are refused), and values — capacities
    must be finite and non-negative (a negative or NaN capacity breaks the
    residual-graph invariants silently rather than loudly).
    """
    try:
        cap, cs, ct = (jnp.asarray(a) for a in problem)
    except (TypeError, ValueError) as e:
        raise ValueError(f"malformed grid problem: not array-like ({e})")
    if cap.ndim != 3 or cap.shape[0] != 4 or cs.shape != ct.shape \
            or cs.shape != cap.shape[1:]:
        raise ValueError(
            f"malformed grid problem: cap_nbr {cap.shape}, "
            f"cap_src {cs.shape}, cap_sink {ct.shape}; expected "
            f"(4, H, W) / (H, W) / (H, W)")
    for name, a in (("cap_nbr", cap), ("cap_src", cs), ("cap_sink", ct)):
        if not (jnp.issubdtype(a.dtype, jnp.floating)
                or jnp.issubdtype(a.dtype, jnp.integer)):
            raise ValueError(
                f"malformed grid problem: {name} has non-numeric dtype "
                f"{a.dtype} (need integer or floating capacities)")
        v = np.asarray(a)
        if not np.all(np.isfinite(v)):
            raise ValueError(
                f"malformed grid problem: {name} contains non-finite "
                f"capacities (NaN/inf)")
        if np.any(v < 0):
            raise ValueError(
                f"malformed grid problem: {name} contains negative "
                f"capacities (min={v.min()})")
    return GridProblem(cap, cs, ct)


def validate_assignment_matrix(w) -> np.ndarray:
    """Canonicalize + validate an assignment request (square int matrix)."""
    w = np.asarray(w)
    if w.ndim != 2 or w.shape[0] != w.shape[1] \
            or not np.issubdtype(w.dtype, np.integer):
        raise ValueError(
            f"malformed assignment request: need a square integer "
            f"matrix, got shape {w.shape} dtype {w.dtype}")
    return w


class SolverEngine:
    """Request queue -> pad-and-bucket -> (sharded) batched solve.

    The serving front door for the paper's two solvers. Callers ``submit_*``
    problems as they arrive and receive integer tickets; ``flush()`` solves
    everything pending — max-flow requests through
    ``repro.core.batch.solve_maxflow_batch`` and assignment requests through
    ``solve_assignment_batch`` — and returns ``{ticket: result}``. Results
    are exactly what the direct front-end calls would return (same padding,
    same bucketing, bit-identical values), so correctness is inherited from
    the tested batch path.

    Partial-failure contract: ``flush`` solves one kind at a time and
    DELIVERS each kind the moment it completes (into an internal ready
    buffer). If a later kind's batch raises, the exception propagates, but
    the completed kinds' results are NOT discarded — they are returned by
    the next successful ``flush`` without being re-solved, and only the
    failing kind's queue stays populated for retry.

    Args:
      mesh / mesh_axis: optional ``jax.sharding.Mesh``
        (``repro.launch.mesh.make_solver_mesh``) — each bucket's batch axis
        is sharded across the mesh; ragged bucket sizes are padded with
        inert instances automatically.
      bucket: bucketing policy for ragged queues (``"max"`` | ``"pow2"`` |
        ``"exact"``, see docs/batching.md).
      compact: early-exit compaction of each bucket's batch (the
        ``compact=`` knob of ``repro.core.batch`` / the solvers): requests
        that converge early are dropped from the working set between cycle
        segments instead of being select-masked until the bucket's slowest
        request finishes. Off by default; worth opting into for serving
        queues, whose convergence is naturally ragged (see
        benchmarks/RESULTS_compaction.md). Results stay bit-identical.
      maxflow_kw / assignment_kw: per-kind solver keyword overrides
        (``backend=``, ``method=``, ``max_rounds=``, ...).
    """

    def __init__(self, *, mesh=None, mesh_axis: str | None = None,
                 bucket: str = "max", compact: bool = False,
                 maxflow_kw: dict | None = None,
                 assignment_kw: dict | None = None):
        self.mesh, self.mesh_axis, self.bucket = mesh, mesh_axis, bucket
        self.compact = compact
        self.maxflow_kw = dict(maxflow_kw or {})
        self.assignment_kw = dict(assignment_kw or {})
        self._next_ticket = 0
        self._maxflow: list[tuple[int, Any]] = []
        self._assignment: list[tuple[int, Any]] = []
        # results of kinds that completed before a later kind's flush raised
        self._ready: dict[int, Any] = {}

    def _ticket(self) -> int:
        t, self._next_ticket = self._next_ticket, self._next_ticket + 1
        return t

    def submit_maxflow(self, problem) -> int:
        """Queue a ``GridProblem`` (any (H, W)); returns its ticket.

        Malformed requests — wrong shapes, non-numeric dtypes, negative or
        non-finite capacities — are rejected HERE (before a ticket is
        issued, ``validate_grid_problem``) so ``flush`` cannot be wedged by
        a bad queue entry.
        """
        problem = validate_grid_problem(problem)
        t = self._ticket()
        self._maxflow.append((t, problem))
        return t

    def submit_assignment(self, w) -> int:
        """Queue a square integer weight matrix (any n); returns its ticket.

        Rejects non-square or non-integer matrices at submit time
        (``validate_assignment_matrix`` — same reject-before-ticket
        contract as ``submit_maxflow``).
        """
        w = validate_assignment_matrix(w)
        t = self._ticket()
        self._assignment.append((t, w))
        return t

    def pending(self) -> int:
        """Number of queued, unsolved requests."""
        return len(self._maxflow) + len(self._assignment)

    # ---- the synchronous core the async scheduler drives ----------------

    def prepare(self, kind: str, payloads: list) -> list[PreparedBucket]:
        """HOST stage: pad-and-bucket ``payloads`` of one kind.

        Pure host work (``repro.core.batch.prepare_*_buckets`` with this
        engine's bucket/mesh config) — the stage the async scheduler
        overlaps with the previous batch's device solve.
        """
        if kind == "maxflow":
            return prepare_maxflow_buckets(
                payloads, bucket=self.bucket, mesh=self.mesh,
                mesh_axis=self.mesh_axis)
        if kind == "assignment":
            return prepare_assignment_buckets(
                payloads, bucket=self.bucket, mesh=self.mesh,
                mesh_axis=self.mesh_axis)
        raise ValueError(f"unknown request kind: {kind!r}")

    def solve_prepared(self, prep: PreparedBucket, *,
                       compact: bool | None = None) \
            -> tuple[dict[int, Any], BucketStats]:
        """DEVICE stage: dispatch one prepared bucket.

        ``compact=None`` uses the engine default; the async scheduler
        overrides it per dispatch (adaptive masked-vs-compacted choice).
        Returns ``({payload_position: result}, BucketStats)``.
        """
        compact = self.compact if compact is None else compact
        if prep.kind == "maxflow":
            return solve_prepared_maxflow(
                prep, compact=compact, mesh=self.mesh,
                mesh_axis=self.mesh_axis, **self.maxflow_kw)
        return solve_prepared_assignment(
            prep, compact=compact, mesh=self.mesh,
            mesh_axis=self.mesh_axis, **self.assignment_kw)

    def solve_requests(self, kind: str, payloads: list, *,
                       compact: bool | None = None,
                       stats_out: list | None = None) -> list:
        """Solve ``payloads`` of one kind; results in input order.

        ``prepare`` + ``solve_prepared`` composed back-to-back — the
        blocking path ``flush`` uses, and the poison-isolation fallback of
        the async scheduler (one payload at a time).
        """
        results = [None] * len(payloads)
        for prep in self.prepare(kind, payloads):
            out, stats = self.solve_prepared(prep, compact=compact)
            if stats_out is not None:
                stats_out.append(stats)
            for i, r in out.items():
                results[i] = r
        return results

    def flush(self, *, stats_out: list | None = None) -> dict[int, Any]:
        """Solve every pending request; returns ``{ticket: result}``.

        One batched dispatch per (kind, bucket shape); a flushed kind's
        queue is emptied even if a request did not converge (check
        ``result.converged``). An empty queue returns ``{}`` without
        dispatching. If one kind's batch raises, kinds that already
        completed stay delivered (returned by the next flush, not
        re-solved) and only the failing kind remains queued.
        """
        if self._maxflow:
            tickets, probs = zip(*self._maxflow)
            res = self.solve_requests("maxflow", list(probs),
                                      stats_out=stats_out)
            self._ready.update(zip(tickets, res))
            self._maxflow.clear()
        if self._assignment:
            tickets, ws = zip(*self._assignment)
            res = self.solve_requests("assignment", list(ws),
                                      stats_out=stats_out)
            self._ready.update(zip(tickets, res))
            self._assignment.clear()
        out, self._ready = self._ready, {}
        return out


def greedy_generate(cfg, params, axes, shd, prompt_tokens, max_new: int,
                    S_max: int | None = None):
    """Reference end-to-end generation loop (examples/tests)."""
    B, S = prompt_tokens.shape
    S_max = S_max or (S + max_new + 1)
    caches, _ = init_caches(cfg, B, S_max, dtype=jnp.float32)
    prefill = make_prefill_step(cfg, axes, None, shd)
    nxt, state = prefill(params, prompt_tokens, caches)
    step = make_serve_step(cfg, axes, shd)
    toks = [nxt]
    for _ in range(max_new - 1):
        nxt, state = step(params, state)
        toks.append(nxt)
    return jnp.stack(toks, axis=1)
