"""Serving: batched model decode AND the batched-solver request path.

Two engines live here:

* the LLM path — ``make_prefill_step`` / ``make_serve_step``: one new token
  for every request in the batch against a full KV/SSM cache (what the
  decode_* / long_* dry-run shapes lower), and
* the solver path — ``SolverEngine``: the ROADMAP's request-queue →
  pad-and-bucket → (mesh-sharded) batched-solve pipeline for the
  registered solver kinds (``repro.core.kinds``). Requests of mixed kinds
  and ragged shapes are queued with ``submit(kind, payload)`` and solved
  together on ``flush()`` — payloads are bucketed and padded by each
  kind's registered host stage, every bucket is one jitted dispatch, and
  an optional device mesh shards each bucket's batch axis (``shard_map``,
  zero cross-device traffic; see docs/batching.md). The engine itself
  never names a kind: a new solver registered with the registry serves
  through it unchanged (docs/solvers.md).

``SolverEngine`` is also the SYNCHRONOUS CORE of the async serving
scheduler (``repro.serve.scheduler.AsyncSolverEngine``): the scheduler
drives the engine's two-stage ``prepare`` (host pad-and-bucket) /
``solve_prepared`` (device dispatch) split so batch *k+1*'s host work
overlaps batch *k*'s device solve — see docs/serving.md.
"""
from __future__ import annotations

import time
import warnings
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
# Validators moved to repro.core.batch (each kind registers its own);
# re-exported here because this was their historical home.
from repro.core.batch import (BucketStats, PreparedBucket,  # noqa: F401
                              validate_assignment_matrix,
                              validate_grid_problem)
from repro.core.kinds import get_kind
from repro.models.layers import Sharder
from repro.obs.trace import current_tracer, step_annotation
from repro.models.model import apply_model, init_caches


class ServeState(NamedTuple):
    caches: Any
    last_tokens: jax.Array    # (B,) most recent token per request
    lengths: jax.Array        # (B,) current sequence lengths


def make_prefill_step(cfg: ModelConfig, axes, cache_axes, shd: Sharder):
    def prefill(params, tokens, caches):
        """tokens: (B, S). Returns (first generated token, ServeState)."""
        out = apply_model(params, axes, cfg, shd, {"tokens": tokens},
                          caches=caches, logits_mode="last")
        nxt = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)
        B, S = tokens.shape
        return nxt, ServeState(out.caches, nxt,
                               jnp.full((B,), S, jnp.int32))
    return prefill


def make_serve_step(cfg: ModelConfig, axes, shd: Sharder,
                    pos_offset: int | None = None):
    """Decode one token for the whole batch (the dry-run `serve_step`).

    pos_offset=None reads the position from state.lengths (traced), so one
    compiled step serves every decode position.
    """
    def serve_step(params, state: ServeState):
        off = state.lengths[0] if pos_offset is None else pos_offset
        out = apply_model(params, axes, cfg, shd,
                          {"tokens": state.last_tokens[:, None]},
                          caches=state.caches, decode=True,
                          pos_offset=off, logits_mode="last")
        nxt = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, ServeState(out.caches, nxt, state.lengths + 1)
    return serve_step


def _merge_deprecated_kw(solver_kw: dict | None, maxflow_kw: dict | None,
                         assignment_kw: dict | None,
                         owner: str) -> dict[str, dict]:
    """Fold the legacy per-kind kwargs into ``solver_kw`` (with warnings)."""
    merged = {k: dict(v) for k, v in (solver_kw or {}).items()}
    for kind, kw, name in (("maxflow", maxflow_kw, "maxflow_kw"),
                           ("assignment", assignment_kw, "assignment_kw")):
        if kw is not None:
            warnings.warn(
                f"{owner}({name}=...) is deprecated; use "
                f"solver_kw={{{kind!r}: {{...}}}}",
                DeprecationWarning, stacklevel=3)
            merged.setdefault(kind, {}).update(kw)
    return merged


class SolverEngine:
    """Request queue -> pad-and-bucket -> (sharded) batched solve.

    The serving front door for every registered solver kind. Callers
    ``submit(kind, payload)`` problems as they arrive and receive integer
    tickets; ``flush()`` solves everything pending — each kind through its
    registered host/device stages (``repro.core.kinds``) — and returns
    ``{ticket: result}``. Results are exactly what the direct front-end
    calls (``repro.core.batch.solve_batch``) would return (same padding,
    same bucketing, bit-identical values), so correctness is inherited
    from the tested batch path.

    Partial-failure contract: ``flush`` solves one kind at a time and
    DELIVERS each kind the moment it completes (into an internal ready
    buffer). If a later kind's batch raises, the exception propagates, but
    the completed kinds' results are NOT discarded — they are returned by
    the next successful ``flush`` without being re-solved, and only the
    failing kind's queue stays populated for retry.

    Args:
      mesh / mesh_axis: optional ``jax.sharding.Mesh``
        (``repro.launch.mesh.make_solver_mesh``) — each bucket's batch axis
        is sharded across the mesh; ragged bucket sizes are padded with
        inert instances automatically.
      bucket: bucketing policy for ragged queues (``"max"`` | ``"pow2"`` |
        ``"exact"``, see docs/batching.md).
      compact: early-exit compaction of each bucket's batch (the
        ``compact=`` knob of ``repro.core.batch`` / the solvers): requests
        that converge early are dropped from the working set between cycle
        segments instead of being select-masked until the bucket's slowest
        request finishes. Off by default; worth opting into for serving
        queues, whose convergence is naturally ragged (see
        benchmarks/RESULTS_compaction.md). Results stay bit-identical.
      solver_kw: per-kind solver keyword overrides, keyed by kind name —
        ``{"maxflow": {"backend": ...}, "matching": {"max_rounds": ...}}``.
      maxflow_kw / assignment_kw: DEPRECATED — the pre-registry spelling of
        ``solver_kw`` for the two original kinds; folded into
        ``solver_kw`` with a ``DeprecationWarning``.
      tracer: optional ``repro.obs.Tracer`` recording lifecycle spans
        (``submit`` / ``bucket/pad`` / ``device-solve``) through this
        engine. Defaults to the AMBIENT tracer at construction time
        (``repro.obs.use_tracer`` — captured once, because contextvars do
        not cross the threads a scheduler may drive this engine from);
        ``None`` (no ambient tracer) records nothing and costs one
        ``None`` check per stage.
      cache: optional ``repro.core.warm.SolutionCache`` backing the
        incremental re-solve path (``submit(..., base=, delta=)``, see
        docs/warmstart.md). Defaults to a private per-engine cache;
        pass a shared one to pool solutions across engines. Every solved
        request of a kind with a registered ``solution_of`` hook is
        cached, so any prior ticket can seed a warm re-solve.
      metrics: optional ``repro.serve.metrics.SchedulerMetrics`` — the
        engine records cache lookups and warm/cold solve composition into
        it (the async scheduler threads its own through here).
    """

    def __init__(self, *, mesh=None, mesh_axis: str | None = None,
                 bucket: str = "max", compact: bool = False,
                 solver_kw: dict[str, dict] | None = None,
                 maxflow_kw: dict | None = None,
                 assignment_kw: dict | None = None,
                 tracer=None, cache=None, metrics=None):
        from repro.core.warm import SolutionCache
        self.mesh, self.mesh_axis, self.bucket = mesh, mesh_axis, bucket
        self.compact = compact
        self.tracer = tracer if tracer is not None else current_tracer()
        self.cache = cache if cache is not None else SolutionCache()
        self.metrics = metrics
        self.solver_kw = _merge_deprecated_kw(
            solver_kw, maxflow_kw, assignment_kw, "SolverEngine")
        self._next_ticket = 0
        # per-kind queues, keyed lazily on first submit; dict insertion
        # order fixes the kind order of flush (and so of the
        # partial-failure delivery contract)
        self._queues: dict[str, list[tuple[int, Any]]] = {}
        # results of kinds that completed before a later kind's flush raised
        self._ready: dict[int, Any] = {}
        # ticket -> (kind, cache key) for every solved request whose kind
        # registered a solution_of hook — lets submit(base=ticket) resolve
        self._key_of_ticket: dict[int, tuple[str, str]] = {}
        # ticket -> WarmStart for queued warm requests
        self._warm_of_ticket: dict[int, Any] = {}

    def _ticket(self) -> int:
        t, self._next_ticket = self._next_ticket, self._next_ticket + 1
        return t

    def _resolve_base(self, kind: str, base):
        """``submit(base=)`` -> ``(base_problem, solution)`` or raise.

        ``base`` is a prior ticket of this engine (int) or a
        ``SolutionCache`` content key (str). Records the lookup hit/miss;
        a miss raises ``KeyError`` — warm submission demands its seed, the
        caller falls back to a plain cold ``submit`` explicitly.
        """
        if isinstance(base, int):
            mapped = self._key_of_ticket.get(base)
            if mapped is None or mapped[0] != kind:
                if self.metrics is not None:
                    self.metrics.record_cache_lookup(False)
                raise KeyError(
                    f"base ticket {base} has no cached {kind!r} solution "
                    f"(unsolved, evicted, or a different kind)")
            base = mapped[1]
        hit = self.cache.get(base)
        if self.metrics is not None:
            self.metrics.record_cache_lookup(hit is not None)
        if hit is None:
            raise KeyError(
                f"no cached solution under key {base!r} (evicted?)")
        return hit.problem, hit.solution

    def submit(self, kind: str, payload=None, *, base=None, delta=None) -> int:
        """Queue one request of a registered kind; returns its ticket.

        Malformed payloads are rejected HERE, by the kind's registered
        validator, BEFORE a ticket is issued — so ``flush`` cannot be
        wedged by a bad queue entry. Unknown kinds raise ``ValueError``
        naming the registered ones.

        Incremental re-solve (docs/warmstart.md): pass ``base=`` — a prior
        ticket of this engine or a ``SolutionCache`` key — to warm-start
        from that solved instance. ``delta`` (a ``GraphDelta`` or sequence)
        then derives the new payload from the base problem when ``payload``
        is ``None``; an explicit ``payload`` with ``base=`` warm-starts
        that payload directly. A ``base`` with no cached solution raises
        ``KeyError`` (the caller retries cold).
        """
        t0 = time.monotonic() if self.tracer is not None else 0.0
        ws = None
        if base is not None:
            from repro.core.warm import WarmStart, apply_delta
            bp, solution = self._resolve_base(kind, base)
            if payload is None:
                if delta is None:
                    raise ValueError(
                        "submit(base=...) needs a payload or a delta to "
                        "derive one")
                payload = apply_delta(kind, bp, delta)
            elif delta is not None:
                payload = apply_delta(kind, payload, delta)
            ws = WarmStart(solution, base_problem=bp)
        elif delta is not None:
            raise ValueError("submit(delta=...) needs base= to apply it to")
        elif payload is None:
            raise ValueError("submit() needs a payload (or base=/delta=)")
        payload = get_kind(kind).validate(payload)
        t = self._ticket()
        self._queues.setdefault(kind, []).append((t, payload))
        if ws is not None:
            self._warm_of_ticket[t] = ws
        if self.tracer is not None:
            self.tracer.record("submit", t0, time.monotonic(),
                               ticket=t, kind=kind,
                               init="warm" if ws is not None else "cold")
        return t

    def submit_maxflow(self, problem) -> int:
        """DEPRECATED: use ``submit("maxflow", problem)``."""
        warnings.warn(
            'submit_maxflow(...) is deprecated; use submit("maxflow", ...)',
            DeprecationWarning, stacklevel=2)
        return self.submit("maxflow", problem)

    def submit_assignment(self, w) -> int:
        """DEPRECATED: use ``submit("assignment", w)``."""
        warnings.warn(
            'submit_assignment(...) is deprecated; use '
            'submit("assignment", ...)', DeprecationWarning, stacklevel=2)
        return self.submit("assignment", w)

    def pending(self) -> int:
        """Number of queued, unsolved requests."""
        return sum(len(q) for q in self._queues.values())

    # ---- the synchronous core the async scheduler drives ----------------

    def prepare(self, kind: str, payloads: list) -> list[PreparedBucket]:
        """HOST stage: pad-and-bucket ``payloads`` of one kind.

        Pure host work (the kind's registered ``prepare_buckets`` with
        this engine's bucket/mesh config) — the stage the async scheduler
        overlaps with the previous batch's device solve.
        """
        if self.tracer is None:
            return get_kind(kind).prepare_buckets(
                payloads, bucket=self.bucket, mesh=self.mesh,
                mesh_axis=self.mesh_axis)
        with self.tracer.span("bucket/pad", kind=kind, n=len(payloads)):
            return get_kind(kind).prepare_buckets(
                payloads, bucket=self.bucket, mesh=self.mesh,
                mesh_axis=self.mesh_axis)

    def solve_prepared(self, prep: PreparedBucket, *,
                       compact: bool | None = None) \
            -> tuple[dict[int, Any], BucketStats]:
        """DEVICE stage: dispatch one prepared bucket.

        ``compact=None`` uses the engine default; the async scheduler
        overrides it per dispatch (adaptive masked-vs-compacted choice).
        Returns ``({payload_position: result}, BucketStats)``.
        """
        compact = self.compact if compact is None else compact
        if self.tracer is None:
            return get_kind(prep.kind).solve_prepared(
                prep, compact=compact, mesh=self.mesh,
                mesh_axis=self.mesh_axis,
                **self.solver_kw.get(prep.kind, {}))
        driver = "compacted" if compact else "masked"
        with self.tracer.span("device-solve", kind=prep.kind,
                              bucket=list(prep.shape),
                              n_real=len(prep.idxs), driver=driver,
                              init="cold"), \
                step_annotation(f"solve:{prep.kind}"):
            return get_kind(prep.kind).solve_prepared(
                prep, compact=compact, mesh=self.mesh,
                mesh_axis=self.mesh_axis,
                **self.solver_kw.get(prep.kind, {}))

    def solve_requests(self, kind: str, payloads: list, *,
                       compact: bool | None = None,
                       stats_out: list | None = None,
                       warm: dict | None = None) -> list:
        """Solve ``payloads`` of one kind; results in input order.

        ``prepare`` + ``solve_prepared`` composed back-to-back — the
        blocking path ``flush`` uses, and the poison-isolation fallback of
        the async scheduler (one payload at a time). A non-empty ``warm``
        (``{payload_position: WarmStart}``) routes the whole batch through
        the per-instance warm/cold seam (``repro.core.warm.solve_warm``)
        instead — results stay in input order and reach the same optima
        (tests/test_warm.py).
        """
        if warm:
            from repro.core.warm import solve_warm
            compact = self.compact if compact is None else compact
            kw = dict(bucket=self.bucket, compact=compact, mesh=self.mesh,
                      mesh_axis=self.mesh_axis, stats_out=stats_out,
                      **self.solver_kw.get(kind, {}))
            if self.tracer is None:
                return solve_warm(kind, payloads, warm, **kw)
            with self.tracer.span("device-solve", kind=kind,
                                  n_real=len(payloads),
                                  n_warm=len(warm), init="warm"), \
                    step_annotation(f"solve:{kind}"):
                return solve_warm(kind, payloads, warm, **kw)
        results = [None] * len(payloads)
        for prep in self.prepare(kind, payloads):
            out, stats = self.solve_prepared(prep, compact=compact)
            if stats_out is not None:
                stats_out.append(stats)
            for i, r in out.items():
                results[i] = r
        return results

    def flush(self, *, stats_out: list | None = None) -> dict[int, Any]:
        """Solve every pending request; returns ``{ticket: result}``.

        One batched dispatch per (kind, bucket shape), kinds in
        first-submission order; a flushed kind's queue is emptied even if
        a request did not converge (check ``result.converged``). An empty
        queue returns ``{}`` without dispatching. If one kind's batch
        raises, kinds that already completed stay delivered (returned by
        the next flush, not re-solved) and only the failing kind remains
        queued. Requests submitted WHILE a flush is solving are never
        dropped: they stay queued for the next flush, and the returned
        dict is ticket-ordered.
        """
        for kind in list(self._queues):
            q = self._queues[kind]
            if not q:
                continue
            tickets, payloads = zip(*q)
            warm_map = {i: self._warm_of_ticket[t]
                        for i, t in enumerate(tickets)
                        if t in self._warm_of_ticket}
            res = self.solve_requests(kind, list(payloads),
                                      stats_out=stats_out, warm=warm_map)
            self._ready.update(zip(tickets, res))
            self.record_solved(kind, tickets, payloads, res,
                               warm_idx=tuple(warm_map))
            # Drop exactly the entries this flush solved — NOT q.clear():
            # a submit that lands while solve_requests is running (e.g.
            # from a callback or another thread) appends behind the
            # snapshot, and clearing would silently discard it.
            del q[:len(tickets)]
        out, self._ready = dict(sorted(self._ready.items())), {}
        return out

    def record_solved(self, kind: str, tickets, payloads, results, *,
                      warm_idx=()) -> None:
        """Post-solve bookkeeping for one kind's batch (flush and the
        async scheduler both route through here).

        Caches every result's solution artifact (kinds with a
        ``solution_of`` hook) so any solved ticket can seed a later
        ``submit(base=ticket)``, drops the tickets' pending warm seeds,
        and records the batch's warm/cold composition — including the
        rounds-saved signal when the kind has a cold-rounds EWMA baseline
        (``SchedulerMetrics.record_warm``).
        """
        k = get_kind(kind)
        for t, p, r in zip(tickets, payloads, results):
            self._warm_of_ticket.pop(t, None)
            if r is None or k.solution_of is None:
                continue
            key = self.cache.put(kind, p, k.solution_of(r))
            self._key_of_ticket[t] = (kind, key)
        if self.metrics is None or not tickets:
            return
        n_warm = len(warm_idx)
        rounds_saved = None
        cold_ewma = self.metrics.convergence.rounds(kind)
        warm_rounds = [float(results[i].rounds) for i in warm_idx
                       if results[i] is not None
                       and getattr(results[i], "rounds", None) is not None]
        if cold_ewma is not None and warm_rounds:
            rounds_saved = cold_ewma - sum(warm_rounds) / len(warm_rounds)
        self.metrics.record_warm(kind, n_warm, len(tickets) - n_warm,
                                 rounds_saved)

    def refill_session(self, kind: str, *, shape, capacity: int,
                       **overrides):
        """A continuous-batching session of ``kind`` on this engine's mesh.

        Builds a ``repro.core.refill.RefillSolver`` carrying the engine's
        mesh/mesh_axis and per-kind ``solver_kw`` (so the deprecated
        ``maxflow_kw`` / ``assignment_kw`` spellings flow into the refill
        path too); ``overrides`` take precedence.  Raises ``ValueError``
        for kinds without a registered refill runtime.
        """
        from repro.core.refill import RefillSolver
        kw = {**self.solver_kw.get(kind, {}), **overrides}
        kw.setdefault("tracer", self.tracer)
        return RefillSolver(kind, shape=shape, capacity=capacity,
                            mesh=self.mesh, mesh_axis=self.mesh_axis, **kw)


def greedy_generate(cfg, params, axes, shd, prompt_tokens, max_new: int,
                    S_max: int | None = None):
    """Reference end-to-end generation loop (examples/tests)."""
    B, S = prompt_tokens.shape
    S_max = S_max or (S + max_new + 1)
    caches, _ = init_caches(cfg, B, S_max, dtype=jnp.float32)
    prefill = make_prefill_step(cfg, axes, None, shd)
    nxt, state = prefill(params, prompt_tokens, caches)
    step = make_serve_step(cfg, axes, shd)
    toks = [nxt]
    for _ in range(max_new - 1):
        nxt, state = step(params, state)
        toks.append(nxt)
    return jnp.stack(toks, axis=1)
