"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs / (chips · 197 TFLOP/s bf16)
    memory     = HLO_bytes_accessed / (chips · 819 GB/s HBM)
    collective = Σ collective operand bytes / (chips · 50 GB/s ICI link)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are NOT
in cost_analysis — they are parsed from the HLO text by summing the shaped
outputs of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op. cost_analysis sums over all devices' work in SPMD, so
both numerators are whole-step quantities and the division by `chips`
normalizes to per-chip wall time.
"""
from __future__ import annotations

import dataclasses
import re


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across JAX versions to a dict.

    Older jaxlibs return a single dict; newer ones return a LIST of
    per-program dicts (and ``None`` is possible when analysis is
    unavailable). Accepts either the compiled executable or the raw
    ``cost_analysis()`` return value. A single-entry list unwraps to that
    entry; multi-entry lists merge by summing numeric values (keeping the
    first occurrence of non-numeric ones).
    """
    ca = compiled.cost_analysis() if hasattr(compiled, "cost_analysis") \
        else compiled
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return dict(ca)
    entries = [e for e in ca if isinstance(e, dict)]
    if len(entries) == 1:
        return dict(entries[0])
    out: dict = {}
    for entry in entries:
        for k, v in entry.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                out[k] = out.get(k, 0.0) + float(v)
            else:
                out.setdefault(k, v)
    return out

PEAK_FLOPS = 197e12          # bf16 per chip, TPU v5e
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Bytes moved by each collective kind (sum of output shapes)."""
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape, kind = m.group(1), m.group(2).lower()
        out[kind] = out.get(kind, 0) + _shape_bytes(shape)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_breakdown: dict
    model_flops: float          # 6·N_active·tokens (theory)
    bytes_per_chip: float       # peak memory per device (memory_analysis)

    # NOTE: flops/bytes/coll_bytes are PER-DEVICE program quantities (the
    # SPMD module is per-chip); whole-step totals are these × chips. The
    # spec formulas divide global HLO numbers by chips — identical result.
    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self):
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def useful_flops_frac(self):
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_frac(self):
        """Fraction of the compute roofline the step achieves if every term
        overlaps perfectly: model_flops time / max(all terms)."""
        t_model = self.model_flops / (self.chips * PEAK_FLOPS)
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        return t_model / t_step if t_step else 0.0

    def row(self):
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.t_compute*1e3:.1f} | {self.t_memory*1e3:.1f} | "
                f"{self.t_collective*1e3:.1f} | {self.bottleneck} | "
                f"{self.useful_flops_frac:.2f} | {self.roofline_frac:.2f} |")


def model_flops_for(cfg, shape_info) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) for train; 2·N for decode/prefill
    forward-only (per generated/processed token)."""
    S, B = shape_info["seq_len"], shape_info["global_batch"]
    n_active = cfg.active_param_count()
    if shape_info["kind"] == "train":
        tokens = S * B
        return 6.0 * n_active * tokens
    if shape_info["kind"] == "prefill":
        return 2.0 * n_active * S * B
    return 2.0 * n_active * B          # decode: one token per request
