"""Trip-count-exact roofline accounting from post-optimization HLO text.

``compiled.cost_analysis()`` counts every while (= lax.scan) body ONCE, so a
96-layer model's FLOPs are undercounted ~96x. This parser rebuilds the call
graph (entry -> while bodies -> fusions), multiplies by each while op's
``known_trip_count`` (emitted by XLA for counted loops), and accumulates:

  * flops        — 2·prod(out)·K per dot (matmul-dominated workloads)
  * bytes        — Σ (operands + output) at non-fused op boundaries
  * collectives  — output bytes per all-gather/all-reduce/reduce-scatter/
                   all-to-all/collective-permute, per kind

All numbers are whole-program per-step (SPMD: the per-device program times
the device count happens in the roofline terms' denominators).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_REF_RE = re.compile(r"%([\w.\-]+)")
_CALLED_ONE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_CALLED_LIST = re.compile(r"(?:branch_computations|called_computations)="
                          r"\{([^}]*)\}")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CDIM = re.compile(r"lhs_contracting_dims=\{([0-9,]+)\}")
_LHS_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def _shape_list(s: str):
    return [( dt, [int(d) for d in dims.split(",") if d])
            for dt, dims in _SHAPE_RE.findall(s)]


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _shape_list(s):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Op:
    name: str
    out_shape: str
    opcode: str
    rest: str


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # op name -> out_shape str


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        om = _OP_RE.match(line)
        if om:
            op = Op(om.group(1), om.group(2), om.group(3), om.group(4))
            cur.ops.append(op)
            cur.shapes[op.name] = op.out_shape
    return comps


def _entry_name(hlo: str) -> str | None:
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            if m:
                return m.group(1)
    return None


def _callees(op: Op) -> list[str]:
    names = [m.group(1) for m in _CALLED_ONE.finditer(op.rest)]
    for m in _CALLED_LIST.finditer(op.rest):
        for n in m.group(1).split(","):
            n = n.strip().lstrip("%")
            if n:
                names.append(n)
    return names


def multipliers(comps: dict[str, Computation], entry: str) -> dict[str, float]:
    """Execution count per computation via BFS over the call graph."""
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # process in topological-ish order (HLO call graphs are acyclic)
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        comp = comps.get(name)
        if comp is None:
            continue
        for op in comp.ops:
            callees = _callees(op)
            if not callees:
                continue
            factor = 1.0
            if op.opcode == "while":
                t = _TRIP.search(op.rest)
                factor = float(t.group(1)) if t else 1.0
            for c in callees:
                mult[c] += mult[name] * factor
                if c not in seen:
                    seen.add(c)
                    order.append(c)
    return dict(mult)


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "while", "conditional", "call",
               "custom-call", "partition-id", "replica-id",
               # layout/elementwise ops the TPU compiler fuses into
               # neighbours; on the CPU-backend HLO real elementwise work
               # already sits at fusion boundaries (wrapped_*/fused_*), so
               # counting these raw ops would double-count traffic
               "copy", "convert", "transpose", "reshape", "broadcast",
               "iota", "compare", "select", "add", "subtract", "multiply",
               "divide", "exponential", "negate", "maximum", "minimum",
               "slice", "concatenate", "pad", "copy-start", "copy-done"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _dot_flops(op: Op, comp: Computation) -> float:
    out = _shape_list(op.out_shape)
    if not out:
        return 0.0
    _, out_dims = out[0]
    n_out = 1
    for d in out_dims:
        n_out *= d
    # contracting size: resolve the lhs operand's shape via the symbol
    # table (post-optimization HLO references operands by %name only)
    cd = _CDIM.search(op.rest)
    refs = _REF_RE.findall(op.rest.split(")")[0])
    lhs_dims = None
    if refs and refs[0] in comp.shapes:
        sl = _shape_list(comp.shapes[refs[0]])
        if sl:
            lhs_dims = sl[0][1]
    if lhs_dims is None or not cd:
        return 2.0 * n_out  # degenerate fallback
    k = 1
    for idx in (int(x) for x in cd.group(1).split(",")):
        if idx < len(lhs_dims):
            k *= lhs_dims[idx]
    return 2.0 * n_out * k


def analyze(hlo: str) -> dict:
    comps = parse_computations(hlo)
    entry = _entry_name(hlo)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "collectives": {}}
    mult = multipliers(comps, entry)
    # fusion computations' interiors must not count toward bytes
    fusion_comps = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                fusion_comps.update(_callees(op))

    flops = 0.0
    bytes_acc = 0.0
    colls: dict[str, float] = defaultdict(float)
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        in_fusion = comp.name in fusion_comps
        for op in comp.ops:
            code = op.opcode
            if code in ("dot", "convolution"):
                flops += m * _dot_flops(op, comp)
            if in_fusion:
                continue
            base = code.replace("-start", "")
            if base in _COLLECTIVES:
                b = _shape_bytes(op.out_shape)
                colls[base] += m * b
                bytes_acc += m * b
                continue
            if code in _SKIP_BYTES or code.endswith("-done"):
                continue
            b = _shape_bytes(op.out_shape)
            for ref in _REF_RE.findall(op.rest.split(")")[0]):
                sh = comp.shapes.get(ref)
                if sh:
                    b += _shape_bytes(sh)
            bytes_acc += m * b
    return {"flops": flops, "bytes": bytes_acc,
            "collective_bytes": float(sum(colls.values())),
            "collectives": dict(colls)}
