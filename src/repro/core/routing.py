"""Flow-based MoE token→expert routing (the paper's technique, first-class).

The assignment problem the paper solves (§5) is exactly the balanced-routing
problem of MoE layers: tokens are X, expert *slots* are Y, affinity logits are
edge weights, and expert capacity is the per-Y-node supply (the transportation
framing Goldberg–Kennedy use to model the assignment problem in [9]). We expose
three routers:

  * ``topk_route``    — the standard baseline (top-k + capacity truncation).
  * ``auction_route`` — capacity-constrained ε-auction: the Jacobi bidding
    round of ``repro.core.assignment`` generalized to capacities, run for a
    fixed number of rounds (jit/TPU friendly — fixed shapes, no host sync).
    Guarantees: ≤ k experts per token, ≤ capacity tokens per expert.
  * ``exact_route``   — slot-expanded exact assignment via
    ``solve_assignment`` (small shapes / tests / the paper-faithful oracle).

``auction_route`` is what MoE configs select with ``router = "flow"``.

All routers are shape-polymorphic over leading batch axes: ``scores`` may be
``(T, E)`` or ``(..., T, E)`` (e.g. ``(G, T, E)`` for all of a layer's token
groups, or ``(L, G, T, E)`` for several layers), and every group's assignment
problem is solved in ONE jitted dispatch instead of a vmap/loop of dispatches
— the batched-solver engine of ``repro.core.batch`` applied to MoE routing.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.assignment.cost_scaling import solve_assignment

NEG = -1e9


class Routing(NamedTuple):
    dispatch: jax.Array   # (..., T, E) bool — token t goes to expert e
    combine: jax.Array    # (..., T, E) float — combine weights (0 if dropped)
    prices: jax.Array     # (..., E) final expert prices (auction only; else 0)
    demand: jax.Array     # (..., E) tokens per expert (load-balance metrics)


def _keep_topc_per_expert(score: jax.Array, picked: jax.Array,
                          capacity: int) -> jax.Array:
    """Per-expert capacity enforcement: keep the `capacity` best bidders."""
    bid = jnp.where(picked, score, NEG)
    # rank of each token within its expert column, best first
    order = jnp.argsort(-bid, axis=-2)
    ranks = jnp.argsort(order, axis=-2)
    return picked & (ranks < capacity) & (bid > NEG / 2)


def topk_route(scores: jax.Array, k: int, capacity: int) -> Routing:
    """Baseline: per-token top-k, then per-expert capacity truncation."""
    E = scores.shape[-1]
    _, idx = jax.lax.top_k(scores, k)                  # (..., T, k)
    picked = jnp.any(jax.nn.one_hot(idx, E, dtype=bool), axis=-2)
    kept = _keep_topc_per_expert(scores, picked, capacity)
    gates = jax.nn.softmax(jnp.where(picked, scores, NEG), axis=-1)
    combine = jnp.where(kept, gates, 0.0)
    return Routing(kept, combine,
                   jnp.zeros(scores.shape[:-2] + (E,), scores.dtype),
                   jnp.sum(kept, axis=-2))


def auction_route(scores: jax.Array, k: int, capacity: int,
                  n_iters: int = 8, eps: float = 1e-2) -> Routing:
    """Capacity-constrained ε-auction routing (paper technique, Jacobi rounds).

    Each round every token bids for its current best-k experts at
    price-adjusted affinity; oversubscribed experts raise their price to the
    marginal (capacity-th) bid plus ε, shedding the weakest bidders — the
    dense-bipartite analogue of Algorithm 5.4's relabel. Fixed ``n_iters``
    keeps the op static for pjit; the final truncation guarantees feasibility
    regardless of convergence state. Leading batch axes route every group in
    one dispatch (prices are per group).
    """
    T, E = scores.shape[-2:]
    s = scores.astype(jnp.float32)

    def body(_, q):
        adj = s - q[..., None, :]
        kth = jax.lax.top_k(adj, k)[0][..., -1:]
        picked = adj >= kth
        bids = jnp.where(picked, adj, NEG)
        top_c1 = jax.lax.top_k(jnp.swapaxes(bids, -1, -2),
                               capacity + 1)[0]            # (..., E, C+1)
        demand = jnp.sum(picked, axis=-2)
        over = demand > capacity
        # relabel: raise the price by the gap between the capacity-th and
        # (capacity+1)-th bids + eps — exactly sheds bidders below the cut
        # (the marginal bid plays the role of Alg. 5.4's min c'_p).
        inc = jnp.maximum(top_c1[..., capacity - 1] - top_c1[..., capacity],
                          0.0) + eps
        return jnp.where(over, q + inc, q)

    q0 = jnp.zeros(s.shape[:-2] + (E,), jnp.float32)
    if capacity < T:  # capacity >= T can never oversubscribe: prices stay 0
        q = jax.lax.fori_loop(0, n_iters, body, q0)
    else:
        q = q0

    adj = s - q[..., None, :]
    kth = jax.lax.top_k(adj, k)[0][..., -1:]
    picked = adj >= kth
    kept = _keep_topc_per_expert(adj, picked, capacity)

    # Rescue passes: tokens shed by price rises re-bid for experts with slack
    # (the Jacobi analogue of continuing refine until no active node remains —
    # bounded to 2 passes to keep the op static).
    for _ in range(2):
        slots_used = jnp.sum(kept, axis=-1, keepdims=True)       # (..., T, 1)
        free = (capacity - jnp.sum(kept, axis=-2))[..., None, :]  # (..., 1, E)
        want = jnp.where(kept | (free <= 0) | (slots_used >= k), NEG, adj)
        best = jnp.argmax(want, axis=-1)
        valid = jnp.take_along_axis(want, best[..., None],
                                    -1)[..., 0] > NEG / 2
        extra = jax.nn.one_hot(best, E, dtype=bool) & valid[..., None]
        # re-enforce capacity with incumbents ranked strictly above rescuers
        rank_score = jnp.where(kept, 1e6 + adj, adj)
        kept = _keep_topc_per_expert(rank_score, kept | extra, capacity)

    gates = jax.nn.softmax(jnp.where(kept | picked, s, NEG), axis=-1)
    combine = jnp.where(kept, gates, 0.0).astype(scores.dtype)
    return Routing(kept, combine, q, jnp.sum(kept, axis=-2))


def exact_route(scores: jax.Array, capacity: int,
                weight_scale: int = 1000) -> Routing:
    """Exact k=1 balanced routing by slot-expanded assignment (paper §5).

    Requires T == E * capacity (pad tokens to make it so). Every expert is
    replicated into ``capacity`` slots and the T×T assignment is solved with
    the cost-scaling algorithm — the BASE-layers formulation, i.e. the
    paper's solver used verbatim inside the model stack. Leading batch axes
    solve every group's assignment in one batched dispatch.

    If the solve does not converge (only possible with a pathologically low
    ``max_rounds``; the default always converges), unmatched rows carry the
    solver's >= T sentinel, which maps to an all-False dispatch row — those
    tokens are DROPPED, observable as ``dispatch.sum() < T``, rather than
    silently routed to an arbitrary expert.
    """
    T, E = scores.shape[-2:]
    assert T == E * capacity, "exact_route needs T == E * capacity"
    w = jnp.repeat(scores, capacity, axis=-1)             # (..., T, E*cap)
    w_i = jnp.round(w * weight_scale).astype(jnp.int32)
    res = solve_assignment(w_i, method="auction")
    expert = res.col_of_row // capacity                   # slot -> expert
    dispatch = jax.nn.one_hot(expert, E, dtype=bool)
    gates = jax.nn.softmax(jnp.where(dispatch, scores, NEG), axis=-1)
    combine = jnp.where(dispatch, gates, 0.0)
    prices = -res.p_y.reshape(res.p_y.shape[:-1] + (E, capacity)).mean(-1)
    return Routing(dispatch, combine, prices.astype(scores.dtype),
                   jnp.sum(dispatch, axis=-2))


def solve_transportation(w: jax.Array, supply, capacity,
                         weight_scale: int = 1):
    """Exact max-weight transportation via slot expansion (paper §5 lineage).

    Goldberg–Kennedy [9] model the assignment problem as a transportation
    problem; this goes the other way: integer supplies (per X node) and
    capacities (per Y node) are expanded into unit slots, solved as a
    square assignment with the cost-scaling solver, and folded back.
    Requires Σ supply <= Σ capacity. Dummy rows absorb spare capacity at
    weight 0 (standard padding), so the solution is exactly optimal.

    Returns flow: (n_x, n_y) int32 with row sums == supply, col sums <=
    capacity, maximizing Σ w·flow. Intended for exact k>1 MoE routing
    oracles and tests — the production router is the approximate auction.
    """
    import numpy as np
    w = jnp.asarray(w)
    n_x, n_y = w.shape
    supply = np.asarray(supply, np.int64)
    capacity = np.asarray(capacity, np.int64)
    assert supply.sum() <= capacity.sum(), "infeasible transportation"
    rows = np.repeat(np.arange(n_x), supply)              # unit slots of X
    cols = np.repeat(np.arange(n_y), capacity)            # unit slots of Y
    n = int(capacity.sum())
    big = jnp.zeros((n, n), jnp.int32)
    w_i = jnp.round(w * weight_scale).astype(jnp.int32)
    big = big.at[:len(rows), :].set(w_i[rows][:, cols])   # dummies stay 0
    res = solve_assignment(big, method="auction")
    flow = np.zeros((n_x, n_y), np.int32)
    col_of_row = np.asarray(res.col_of_row[:len(rows)])
    ok = col_of_row < len(cols)  # unmatched sentinel when not converged
    np.add.at(flow, (rows[ok], cols[col_of_row[ok]]), 1)
    return jnp.asarray(flow), res
