"""Reference oracles for the assignment problem (test-time only)."""
from __future__ import annotations

import itertools

import numpy as np
from scipy.optimize import linear_sum_assignment


def optimal_weight(w: np.ndarray) -> int:
    """Exact max-weight perfect matching weight via Hungarian (scipy)."""
    w = np.asarray(w)
    r, c = linear_sum_assignment(w, maximize=True)
    return int(w[r, c].sum())


def optimal_weight_bruteforce(w: np.ndarray) -> int:
    """Brute force for tiny n (cross-check for the cross-check)."""
    n = w.shape[0]
    best = -np.inf
    for perm in itertools.permutations(range(n)):
        best = max(best, sum(w[i, perm[i]] for i in range(n)))
    return int(best)


def eps_optimal(w: np.ndarray, F: np.ndarray, p_x: np.ndarray,
                p_y: np.ndarray, eps: int) -> bool:
    """Check the paper's ε-optimality invariant on the final pseudoflow."""
    n = w.shape[0]
    c = -(n + 1) * np.asarray(w, np.int64)
    cp = c + p_x[:, None].astype(np.int64) - p_y[None, :].astype(np.int64)
    fwd_ok = np.all(cp[F == 0] >= -eps)        # residual X->Y arcs
    rev_ok = np.all(-cp[F == 1] >= -eps)       # residual Y->X arcs
    return bool(fwd_ok and rev_ok)
