"""Cost-scaling assignment (max-weight perfect matching) — paper §5, on TPU.

Implements the paper's Algorithm 5.2 outer loop with the lock-free Refine of
Algorithm 5.4, adapted from CUDA atomics to synchronous Jacobi rounds
(DESIGN.md §2): every active node applies its push/relabel decision to the
pre-round state; the concurrent unit-flow updates commute (disjoint entries of
the dense matching matrix F), so one round is a legal stage-stepping trace in
the sense of the paper's Lemma 5.3.

Representation (complete bipartite, |X| = |Y| = n):
  * costs  c[x, y] = -(n+1) * w[x, y]   (minimization form, Goldberg–Kennedy
    integer scaling: optimality at ε < 1 on the scaled costs = exact optimum)
  * F[x, y] ∈ {0, 1}: the pseudoflow — dense instead of adjacency structs
  * e(x) = 1 - Σ_y F[x, y],  e(y) = Σ_x F[x, y] - 1   (supplies of [9])
  * prices p_x, p_y; part-reduced cost c'_p(x, y) = c(x, y) - p(y)

Heuristics of §5.2/§5.5:
  * arc fixing: arcs with c_p > 2nε never carry flow again — an accumulating
    +INF mask replaces the paper's "flow = -10" adjacency-list deletion,
  * price updates: the Dial-bucket Dijkstra becomes a vectorized Bellman–Ford
    over the dense bipartite graph (same distances; O(n²) per sweep on the
    VPU instead of a host priority queue).

Beyond-paper variant: ``refine="auction"`` fuses push+relabel into a top-2
bid (Bertsekas auction, equivalent ε-scaling semantics) which converges in
fewer Jacobi rounds; the paper-faithful ``refine="pushrelabel"`` is the
baseline recorded in EXPERIMENTS.md.

Batching: every function is shape-polymorphic over leading batch axes —
``w`` may be ``(n, n)`` or ``(B, n, n)``, with prices ``(..., n)``, counters
``(...,)`` and ε carried per instance. Orchestration is delegated to the
unified runtime of ``repro.core.solver_loop``: the nested ε-scaling/refine
loops are flattened into one per-instance cycle (``_ScaleState``) so an
instance that reaches a perfect matching (or finishes its ε-scaling
schedule, which depends on its own max|c|) can be frozen via a select —
masked mode — or dropped from the working set entirely — ``compact=True``,
early-exit compaction — while the rest of the batch keeps refining. Either
way batched results bit-match a loop of single-instance solves.
``solve_assignment`` accepts both ranks; the pad-and-bucket front end for
ragged batches lives in ``repro.core.batch``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.solver_loop import (LoopSpec, masked_events_active,
                                    run_compacted, run_masked)

INF = jnp.int32(2 ** 30)


class AssignmentResult(NamedTuple):
    col_of_row: jax.Array   # (..., n) int32: matched y for each x; the
    #                         sentinel n marks an UNMATCHED row (only
    #                         possible when converged is False)
    weight: jax.Array       # (...,) total matching weight (original scale)
    p_x: jax.Array
    p_y: jax.Array
    rounds: jax.Array       # (...,) total Jacobi rounds across all refines
    pushes: jax.Array       # (...,) total pushes (paper's op-count metric)
    relabels: jax.Array     # (...,) total relabel operations
    converged: jax.Array


class _RefineState(NamedTuple):
    F: jax.Array
    p_x: jax.Array
    p_y: jax.Array
    fixed: jax.Array        # accumulating arc-fixing mask (True = deleted)
    rounds: jax.Array
    pushes: jax.Array
    relabels: jax.Array


def _masked(c, fixed):
    return jnp.where(fixed, INF, c)


def _exp(eps, k: int):
    """ε with k broadcast axes appended: per-instance ε against (..., n[, n])."""
    eps = jnp.asarray(eps)
    return eps.reshape(eps.shape + (1,) * k)


def _freeze(live, new: _RefineState, old: _RefineState) -> _RefineState:
    """Keep ``old`` leaves where ``live`` is False (per-instance no-op)."""
    from repro.core.masking import freeze
    return freeze(live, new, old)


def _round_pushrelabel(c, eps, st: _RefineState, *,
                       backend: str = "xla") -> _RefineState:
    """One Jacobi round of Algorithm 5.4 over all active nodes of both sides."""
    F, p_x, p_y, fixed = st.F, st.p_x, st.p_y, st.fixed
    e1 = _exp(eps, 1)

    row_sum = jnp.sum(F, axis=-1)
    col_sum = jnp.sum(F, axis=-2)
    active_x = row_sum == 0            # e(x) = 1
    active_y = col_sum > 1             # e(y) > 0

    # ---- X side: min part-reduced cost over residual (x,y) = unmatched arcs.
    if backend == "pallas":  # the paper's hot loop as the bidding kernel
        from repro.kernels.bidding.ops import bidding_op
        op = bidding_op
        for _ in range(c.ndim - 2):  # one vmap per leading batch axis
            op = jax.vmap(op)
        min_cpx, arg_x, _ = op(c, p_y, fixed | (F == 1))
    else:
        cpx = _masked(c - p_y[..., None, :], fixed)
        cpx = jnp.where(F == 1, INF, cpx)        # residual X->Y iff F == 0
        min_cpx = jnp.min(cpx, axis=-1)
        arg_x = jnp.argmin(cpx, axis=-1)
    admis_x = min_cpx < -p_x                     # c_p(x, ỹ) < 0 (line 11)
    push_x = active_x & admis_x & (min_cpx < INF)
    relab_x = active_x & ~admis_x & (min_cpx < INF)
    p_x = jnp.where(relab_x, -(min_cpx + e1), p_x)      # line 18

    # ---- Y side: residual (y,x) iff F[x,y] == 1; c'_p(y,x) = -c(x,y) - p(x).
    cpy = jnp.where(F == 1, -c - p_x[..., :, None], INF)    # (x, y) layout
    min_cpy = jnp.min(cpy, axis=-2)
    arg_y = jnp.argmin(cpy, axis=-2)
    admis_y = min_cpy < -p_y
    push_y = active_y & admis_y & (min_cpy < INF)
    relab_y = active_y & ~admis_y & (min_cpy < INF)
    p_y = jnp.where(relab_y, -(min_cpy + e1), p_y)

    # ---- fulfillment: apply all unit pushes at once (disjoint F entries).
    n = c.shape[-1]
    add = (jax.nn.one_hot(arg_x, n, dtype=F.dtype)
           * push_x[..., :, None].astype(F.dtype))
    rem = (jnp.swapaxes(jax.nn.one_hot(arg_y, n, dtype=F.dtype), -1, -2)
           * push_y[..., None, :].astype(F.dtype))
    F = jnp.clip(F + add - rem, 0, 1)

    return _RefineState(
        F=F, p_x=p_x, p_y=p_y, fixed=fixed,
        rounds=st.rounds + 1,
        pushes=st.pushes + jnp.sum(push_x, -1) + jnp.sum(push_y, -1),
        relabels=st.relabels + jnp.sum(relab_x, -1) + jnp.sum(relab_y, -1),
    )


def _round_auction(c, eps, st: _RefineState, *,
                   backend: str = "xla") -> _RefineState:
    """Beyond-paper refine round: top-2 bidding (push+relabel fused).

    Every unmatched x computes its best and second-best part-reduced cost,
    bids its best y down to the second-best level minus ε, and each y accepts
    the single best bid, evicting the previous owner. One round performs the
    work of a push AND the price move a later relabel would do — strictly
    fewer rounds to ε-optimality, same invariants.
    """
    F, p_x, p_y, fixed = st.F, st.p_x, st.p_y, st.fixed
    n = c.shape[-1]

    row_sum = jnp.sum(F, axis=-1)
    active_x = row_sum == 0

    if backend == "pallas":  # top-2 bid via the bidding kernel
        from repro.kernels.bidding.ops import bidding_op
        op = bidding_op
        for _ in range(c.ndim - 2):  # one vmap per leading batch axis
            op = jax.vmap(op)
        min1, arg1, min2 = op(c, p_y, fixed)
    else:
        cpx = _masked(c - p_y[..., None, :], fixed)  # part-reduced costs
        min1 = jnp.min(cpx, axis=-1)
        arg1 = jnp.argmin(cpx, axis=-1)
        cpx2 = jnp.where(jax.nn.one_hot(arg1, n, dtype=bool), INF, cpx)
        min2 = jnp.min(cpx2, axis=-1)
    min2 = jnp.where(min2 >= INF, min1, min2)    # single-candidate rows

    # x is willing to lower p(ỹ)'s attractiveness gap: the winning reduced
    # cost after the bid equals (second best) + ε below nothing — i.e. the
    # new own-price of x would be -(min2 + eps). The bid strength (lower is
    # stronger) is min1 - (min2 + eps) <= -eps < 0.
    bid_strength = min1 - min2 - _exp(eps, 1)    # < 0, more negative = stronger
    bids = jnp.where(
        (jnp.arange(n) == arg1[..., :, None]) & active_x[..., :, None],
        bid_strength[..., :, None], INF)
    best_bid = jnp.min(bids, axis=-2)
    winner = jnp.argmin(bids, axis=-2)
    got_bid = best_bid < INF

    # y accepts the winner: previous owner (if any) is evicted.
    new_match = jax.nn.one_hot(winner, n, dtype=F.dtype, axis=-2) \
        * got_bid[..., None, :].astype(F.dtype)
    F = F * (~got_bid)[..., None, :].astype(F.dtype) + new_match
    # price update on won columns: p(y) absorbs the bid (Bertsekas raise,
    # expressed in Goldberg price coordinates: p_y strictly decreases by >=ε).
    p_y = jnp.where(got_bid, p_y + best_bid, p_y)
    # the winner's own price moves as the later relabel would (ε-CS witness).
    winner_at = jnp.take_along_axis(winner, arg1, axis=-1)
    won = active_x & (winner_at == jnp.arange(n)) \
        & jnp.take_along_axis(got_bid, arg1, axis=-1)
    p_x = jnp.where(won, -(min2 + _exp(eps, 1)), p_x)

    n_push = jnp.sum(got_bid, axis=-1)
    return _RefineState(
        F=F, p_x=p_x, p_y=p_y, fixed=fixed,
        rounds=st.rounds + 1,
        pushes=st.pushes + n_push,
        relabels=st.relabels + n_push,
    )


def _is_perfect(F):
    """Per-instance perfect-matching predicate: scalar or (B,) bool."""
    n = F.shape[-1]
    return (jnp.sum(F, axis=(-2, -1)) == n) \
        & jnp.all(jnp.sum(F, axis=-2) <= 1, axis=-1) \
        & jnp.all(jnp.sum(F, axis=-1) <= 1, axis=-1)


def price_update(c, eps, st: _RefineState, max_sweeps: int) -> _RefineState:
    """Vectorized price-update heuristic (paper Alg. 5.3, Bellman–Ford form).

    Distances (in ε units) from every deficit node (unmatched y) backwards
    along residual arcs; then p(v) -= ε·l(v). Arc length of residual (v,w) is
    max(0, floor(c_p(v,w)/ε) + 1) — identical to the Dial-bucket numbers.
    """
    F, p_x, p_y = st.F, st.p_x, st.p_y
    e1, e2 = _exp(eps, 1), _exp(eps, 2)
    INF_D = jnp.int32(2 ** 26)  # distance infinity (sums stay in int32)
    deficit_y = jnp.sum(F, axis=-2) == 0
    l_y0 = jnp.where(deficit_y, 0, INF_D)

    cp_xy = _masked(c + p_x[..., :, None] - p_y[..., None, :], st.fixed)
    len_xy = jnp.minimum(jnp.maximum(0, cp_xy // e2 + 1), INF_D)  # arc X->Y
    len_xy = jnp.where((F == 0) & (cp_xy < INF), len_xy, INF_D)
    cp_yx = -c + p_y[..., None, :] - p_x[..., :, None]
    len_yx = jnp.where(F == 1, jnp.minimum(
        jnp.maximum(0, cp_yx // e2 + 1), INF_D), INF_D)

    def body(carry):
        l_x, l_y, _, it = carry
        nl_x = jnp.min(jnp.minimum(len_xy + l_y[..., None, :], INF_D), -1)
        nl_x = jnp.minimum(l_x, nl_x)
        # y relaxes through residual (y, x) arcs using the fresh l_x
        nl_y = jnp.min(jnp.minimum(len_yx + nl_x[..., :, None], INF_D), -2)
        nl_y = jnp.minimum(jnp.minimum(l_y, nl_y), l_y0)
        changed = jnp.any(nl_x != l_x) | jnp.any(nl_y != l_y)
        return nl_x, nl_y, changed, it + 1

    def cond(carry):
        return carry[2] & (carry[3] < max_sweeps)

    l_x, l_y, _, _ = jax.lax.while_loop(
        cond, body, (jnp.full_like(p_x, INF_D), l_y0, jnp.bool_(True),
                     jnp.int32(0)))

    reach_x, reach_y = l_x < INF_D, l_y < INF_D
    last = jnp.maximum(jnp.max(jnp.where(reach_x, l_x, 0), axis=-1),
                       jnp.max(jnp.where(reach_y, l_y, 0), axis=-1))
    l_x = jnp.where(reach_x, l_x, last[..., None] + 1)
    l_y = jnp.where(reach_y, l_y, last[..., None] + 1)
    return st._replace(p_x=st.p_x - e1 * l_x, p_y=st.p_y - e1 * l_y)


class _ScaleState(NamedTuple):
    """Flattened per-instance ε-scaling carry for the solver-loop runtime.

    The paper's nested loops — Alg. 5.2's ε schedule around Alg. 5.4's
    refine — are flattened into ONE heuristic cycle so the runtime
    (``repro.core.solver_loop``) can freeze or compact instances at cycle
    granularity: each instance carries its own in-flight ε, its Jacobi-round
    count within the current refine, and its schedule-liveness flag, and the
    cycle performs refine-completion transitions (arc fixing, ε downstep,
    refine re-init) per instance the moment ITS refine finishes — not when
    the whole batch's does. Per-instance state trajectories are identical to
    the nested form (every transition is per-instance pure), which is what
    lets compacted, masked, and single-instance solves bit-match.
    """

    c: jax.Array      # (..., n, n) scaled costs (per-instance constants)
    eps: jax.Array    # (...,) ε of the refine currently in flight
    k: jax.Array      # (...,) Jacobi rounds inside the current refine
    alive: jax.Array  # (...,) bool: ε schedule not yet finished
    st: _RefineState


def _refine_init(c, eps, st: _RefineState) -> _RefineState:
    """Refine entry (Alg. 5.2 lines 3-6): strip the flow, reprice X —
    ``F <- 0; p(x) <- -min_y (c'_p(x,y) + eps)``."""
    cpx = _masked(c - st.p_y[..., None, :], st.fixed)
    return st._replace(F=jnp.zeros_like(st.F),
                       p_x=-(jnp.min(cpx, axis=-1) + _exp(eps, 1)))


def _scale_init(w, *, alpha: int) -> _ScaleState:
    """Initial flat state: per-instance ε = ceil(max|c| / alpha), first
    refine entered (Alg. 5.0 start)."""
    w_i = jnp.asarray(w, jnp.int32)
    n = w_i.shape[-1]
    batch = w_i.shape[:-2]
    c = -(n + 1) * w_i                                   # minimization form
    C = jnp.maximum(jnp.max(jnp.abs(c), axis=(-2, -1)), 1)   # (...,) per inst
    eps0 = jnp.maximum(1, -(-C // alpha))                # eps <- ceil(C/alpha)
    st = _RefineState(
        F=jnp.zeros(batch + (n, n), jnp.int32),
        p_x=jnp.zeros(batch + (n,), jnp.int32),
        p_y=jnp.zeros(batch + (n,), jnp.int32),
        fixed=jnp.zeros(batch + (n, n), jnp.bool_),
        rounds=jnp.zeros(batch, jnp.int32),
        pushes=jnp.zeros(batch, jnp.int32),
        relabels=jnp.zeros(batch, jnp.int32),
    )
    return _ScaleState(c=c, eps=eps0, k=jnp.zeros(batch, jnp.int32),
                       alive=jnp.ones(batch, jnp.bool_),
                       st=_refine_init(c, eps0, st))


def _scale_warm(w, p_y, dmax, *, alpha: int) -> _ScaleState:
    """Warm flat state: re-enter the ε ladder at a delta-bounded rung with
    the prior column prices.

    ``_refine_init`` makes the empty flow EXACTLY ε-optimal for ANY
    ``p_y`` (it reprices every row against the given column prices), so
    warm correctness is unconditional — the ladder still ends at ε = 1,
    where 1-optimality on ``(n+1)``-scaled costs is the exact optimum.
    The prior prices only change how much work is left: a price vector
    that was 1-optimal for the base costs is ``(1 + D)``-optimal for the
    mutated costs, ``D = max |Δc|`` in scaled units, so the ladder can
    start at ``min(1 + D, ε_cold)`` instead of ``ceil(max|c|/α)`` and a
    small delta skips almost every rung.  ``dmax`` is the per-instance
    ``D`` (callers overestimate it freely; it is clamped to the cold ε).
    """
    w_i = jnp.asarray(w, jnp.int32)
    n = w_i.shape[-1]
    batch = w_i.shape[:-2]
    c = -(n + 1) * w_i
    C = jnp.maximum(jnp.max(jnp.abs(c), axis=(-2, -1)), 1)
    eps_cold = jnp.maximum(1, -(-C // alpha))
    eps0 = jnp.clip(1 + jnp.asarray(dmax, jnp.int32), 1, eps_cold)
    st = _RefineState(
        F=jnp.zeros(batch + (n, n), jnp.int32),
        p_x=jnp.zeros(batch + (n,), jnp.int32),
        p_y=jnp.asarray(p_y, jnp.int32),
        fixed=jnp.zeros(batch + (n, n), jnp.bool_),
        rounds=jnp.zeros(batch, jnp.int32),
        pushes=jnp.zeros(batch, jnp.int32),
        relabels=jnp.zeros(batch, jnp.int32),
    )
    return _ScaleState(c=c, eps=eps0, k=jnp.zeros(batch, jnp.int32),
                       alive=jnp.ones(batch, jnp.bool_),
                       st=_refine_init(c, eps0, st))


_scale_warm_jit = jax.jit(_scale_warm, static_argnames=("alpha",))


@functools.lru_cache(maxsize=None)
def _assignment_spec(method: str, alpha: int, max_rounds: int,
                     rounds_per_heuristic: int, use_price_update: bool,
                     use_arc_fixing: bool, backend: str) -> LoopSpec:
    """The assignment solver's registration with the solver-loop runtime.

    One cycle = ``rounds_per_heuristic`` Jacobi rounds of the refine round
    function, the price-update sweep (paper Alg. 5.3), and — for instances
    whose refine just finished (perfect matching or ``max_rounds`` hit) —
    the refine-exit transition: arc fixing at the finished ε, ε downstep,
    and re-entry into the next refine (or schedule death after the ε = 1
    pass). Cached per static-knob tuple so the runtime's jitted drivers
    cache-hit on the spec.
    """
    round_fn = functools.partial(
        {"pushrelabel": _round_pushrelabel,
         "auction": _round_auction}[method], backend=backend)

    def cycle(s: _ScaleState) -> _ScaleState:
        c, eps, k, alive, st = s
        n = c.shape[-1]

        def inner(_, t):
            return round_fn(c, eps, t)

        new = jax.lax.fori_loop(0, rounds_per_heuristic, inner, st)
        if use_price_update:
            perf = _is_perfect(new.F)
            if perf.ndim == 0:  # single instance: genuinely skip the sweep
                new = jax.lax.cond(
                    perf, lambda t: t,
                    lambda t: price_update(c, eps, t, max_sweeps=2 * n), new)
            else:
                new = _freeze(~perf,
                              price_update(c, eps, new, max_sweeps=2 * n),
                              new)
        k = k + rounds_per_heuristic
        done = _is_perfect(new.F) | (k >= max_rounds)
        if use_arc_fixing:
            # Arc fixing at refine exit (paper §5.2, Goldberg [8]): now that
            # f is a genuine ε-optimal FLOW w.r.t. p, any unmatched arc with
            # c_p > 2nε carries zero flow in every ε'-optimal flow with
            # ε' <= ε — freeze it for all subsequent refines. (Matched arcs
            # always satisfy |c_p| <= ε, so only F == 0 arcs can be fixed;
            # the mask replaces the paper's adjacency-list deletion with
            # flow = -10 sentinels.)
            cp = c + new.p_x[..., :, None] - new.p_y[..., None, :]
            fix = new.fixed | ((cp > 2 * n * _exp(eps, 2)) & (new.F == 0))
            new = new._replace(
                fixed=jnp.where(done[..., None, None], fix, new.fixed))
        # ε schedule step for finished refines: divide down, or die after
        # the ε = 1 pass (Goldberg–Kennedy: 1-optimal on scaled costs =
        # exact optimum).
        still = alive & ~(done & (eps <= 1))
        eps_next = jnp.where(done & (eps > 1),
                             jnp.maximum(1, -(-eps // alpha)), eps)
        new = _freeze(done & still, _refine_init(c, eps_next, new), new)
        return _ScaleState(c=c, eps=eps_next, k=jnp.where(done, 0, k),
                           alive=still, st=new)

    def live(s: _ScaleState, rounds: jax.Array) -> jax.Array:
        return s.alive

    return LoopSpec(cycle=cycle, live=live,
                    rounds_per_cycle=rounds_per_heuristic, lead_axes_fn=None)


def _assignment_finalize(w, st: _RefineState) -> AssignmentResult:
    """Matching, weight (original scale), and convergence from a final state.

    Unmatched rows (all-zero F row — possible only when ``max_rounds`` was
    hit before a perfect matching) get the sentinel ``n``, so callers can
    always detect them; matched rows get their argmax column.
    """
    w_i = jnp.asarray(w, jnp.int32)
    n = w_i.shape[-1]
    matched = jnp.sum(st.F, axis=-1) > 0
    col = jnp.where(matched, jnp.argmax(st.F, axis=-1), n)
    weight = jnp.sum(jnp.where(matched, jnp.take_along_axis(
        w_i, jnp.minimum(col, n - 1)[..., :, None], axis=-1)[..., 0], 0),
        axis=-1)
    return AssignmentResult(
        col_of_row=col, weight=weight, p_x=st.p_x, p_y=st.p_y,
        rounds=st.rounds, pushes=st.pushes, relabels=st.relabels,
        converged=_is_perfect(st.F),
    )


@functools.partial(jax.jit, static_argnames=(
    "method", "alpha", "max_rounds", "rounds_per_heuristic",
    "use_price_update", "use_arc_fixing", "backend"))
def _solve_assignment_impl(
    w: jax.Array,
    *,
    method: str,
    alpha: int,
    max_rounds: int,
    rounds_per_heuristic: int,
    use_price_update: bool,
    use_arc_fixing: bool,
    backend: str,
) -> AssignmentResult:
    """Jitted solver body, rank-polymorphic (shard_map-able on (B, n, n)).

    Orchestration lives in ``repro.core.solver_loop.run_masked``: each
    instance runs its own flattened ε-scaling schedule (``_ScaleState``) and
    is frozen via selects once its schedule finishes, while the rest of the
    batch keeps refining.
    """
    state = _scale_init(w, alpha=alpha)
    spec = _assignment_spec(method, alpha, max_rounds, rounds_per_heuristic,
                            use_price_update, use_arc_fixing, backend)
    state, _ = run_masked(spec, state, state.eps.shape)
    return _assignment_finalize(w, state.st)


_scale_init_jit = jax.jit(_scale_init, static_argnames=("alpha",))
_assignment_finalize_jit = jax.jit(_assignment_finalize)


def _solve_assignment_compact(
    w: jax.Array,
    *,
    lanes=None,
    method: str,
    alpha: int,
    max_rounds: int,
    rounds_per_heuristic: int,
    use_price_update: bool,
    use_arc_fixing: bool,
    backend: str,
) -> AssignmentResult:
    """Batched solve with early-exit compaction on the (B,) axis.

    ``run_compacted`` drives the host loop: instances whose ε schedule
    finished are dropped from the working set — still-live ones are
    gathered into dense pow2-sized sub-batches between jitted cycle
    segments — instead of being select-masked until the whole batch drains.
    Results bit-match the masked path (tests/test_compact.py).
    """
    state = _scale_init_jit(jnp.asarray(w, jnp.int32), alpha=alpha)
    spec = _assignment_spec(method, alpha, max_rounds, rounds_per_heuristic,
                            use_price_update, use_arc_fixing, backend)
    state, _ = run_compacted(spec, state, w.shape[0], lanes=lanes)
    return _assignment_finalize_jit(jnp.asarray(w, jnp.int32), state.st)


def _solve_assignment_stepped(
    w: jax.Array,
    *,
    method: str,
    alpha: int,
    max_rounds: int,
    rounds_per_heuristic: int,
    use_price_update: bool,
    use_arc_fixing: bool,
    backend: str,
) -> AssignmentResult:
    """Eager masked solve for cycle telemetry (any batch rank).

    Same init/finalize jits as the compacted path around an eager
    ``run_masked``, which host-steps the jitted cycle under the active
    ``cycle_events(masked=True)`` hook that routed here.  Bit-matches
    ``_solve_assignment_impl`` (tests/test_obs.py).
    """
    w_i = jnp.asarray(w, jnp.int32)
    state = _scale_init_jit(w_i, alpha=alpha)
    spec = _assignment_spec(method, alpha, max_rounds, rounds_per_heuristic,
                            use_price_update, use_arc_fixing, backend)
    state, _ = run_masked(spec, state, state.eps.shape)
    return _assignment_finalize_jit(w_i, state.st)


def solve_assignment(
    w: jax.Array,
    *,
    method: str = "auction",
    alpha: int = 10,
    max_rounds: int = 200_000,
    rounds_per_heuristic: int = 16,
    use_price_update: bool = True,
    use_arc_fixing: bool = True,
    backend: str = "xla",
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
) -> AssignmentResult:
    """Max-weight perfect matching on a complete bipartite graph (paper §5).

    Args:
      w: integer weight matrix — ``(n, n)`` for one instance or ``(B, n, n)``
        for a batch solved in one dispatch (see
        ``repro.core.batch.solve_assignment_batch`` for the ragged
        list-of-matrices front end). Integer weights only (exactness of the
        (n+1)-scaling argument); floats should be pre-quantized by the
        caller. Requires ``n * (n+1) * max|w|`` within int32 range.
      method: ``"auction"`` (beyond-paper top-2 bidding refine, fewer
        rounds) or ``"pushrelabel"`` (paper-faithful Algorithm 5.4).
      alpha: ε-scaling divisor; 10 is the paper's factor (§5.5).
      max_rounds: per-refine Jacobi-round cap; an instance that hits it
        reports ``converged=False`` and may leave rows unmatched (their
        ``col_of_row`` entries hold the sentinel ``n``).
      rounds_per_heuristic: Jacobi rounds between price-update sweeps.
      use_price_update: run the vectorized Bellman–Ford price-update
        heuristic (paper Alg. 5.3).
      use_arc_fixing: freeze arcs with ``c_p > 2nε`` between refines
        (paper §5.2).
      backend: ``"xla"`` or ``"pallas"`` (the bidding/min stage as a TPU
        kernel).
      compact: early-exit compaction (``repro.core.solver_loop``; batched
        ``(B, n, n)`` weights only). Instances whose ε schedule finished
        are dropped from the working set between jitted cycle segments —
        still-live instances are gathered into dense pow2-sized
        sub-batches — instead of being select-masked until the whole batch
        drains. Worth it when convergence is ragged across the batch. With
        ``mesh=``, compaction stays within each shard (one host lane per
        device, no collectives).
      mesh: optional ``jax.sharding.Mesh``
        (``repro.launch.mesh.make_solver_mesh``). Requires batched ``w``
        ``(B, n, n)`` with ``B`` divisible by the shard count; the batch
        axis is then partitioned under ``shard_map`` — each device refines
        its own instances with no cross-device sync (per-instance ε
        schedules and liveness masks already make instances independent),
        and results bit-match the unsharded batched solve
        (tests/test_shard.py).
      mesh_axis: mesh axis to shard over (default: the mesh's first axis).

    Returns:
      ``AssignmentResult`` with leaves leading with the batch axes of ``w``:
      ``col_of_row (..., n)`` (sentinel ``n`` = unmatched row, only when not
      converged), ``weight (...,)`` on the original scale, prices
      ``p_x``/``p_y (..., n)``, operation counters, and ``converged``.

    Convergence contract: each instance runs its own ε-scaling schedule
    (ε starts at that instance's max|c| and divides by ``alpha`` down to 1);
    ``converged=True`` means the final 1-optimal flow is an EXACT optimal
    matching (Goldberg–Kennedy integer scaling). Instances that finish early
    are frozen by liveness masks, so batched results bit-match a loop of
    single-instance solves (tests/test_batch.py).
    """
    kw = dict(method=method, alpha=alpha, max_rounds=max_rounds,
              rounds_per_heuristic=rounds_per_heuristic,
              use_price_update=use_price_update,
              use_arc_fixing=use_arc_fixing, backend=backend)
    if compact:
        if w.ndim != 3:
            raise ValueError(
                f"compact=True needs batched (B, n, n) weights, got shape "
                f"{w.shape}; compaction drops converged instances from a "
                f"batch axis")
        lanes = None
        if mesh is not None:
            from repro.launch.mesh import compact_lanes
            lanes = compact_lanes(mesh, mesh_axis, w.shape[0])
        return _solve_assignment_compact(w, lanes=lanes, **kw)
    if mesh is None:
        if masked_events_active():
            return _solve_assignment_stepped(w, **kw)
        return _solve_assignment_impl(w, **kw)
    if w.ndim != 3:
        raise ValueError(
            f"mesh-sharded solve_assignment needs batched (B, n, n) weights, "
            f"got shape {w.shape}")
    from repro.launch.mesh import dispatch_sharded
    return dispatch_sharded(_solve_assignment_impl, (w,), w.shape[0],
                            mesh, mesh_axis, **kw)
