"""Public surface of the solver core (the paper's algorithms + engine).

The paper's primary contribution — synchronous data-parallel flow and
matching solvers — lives here:

* ``maxflow_grid`` / ``maxflow_grid_batch`` — push-relabel max-flow /
  min-cut on 2-D grid graphs (paper §4), single instance or ``(B, 4, H, W)``
  stacks with per-instance convergence.
* ``solve_assignment`` — cost-scaling max-weight perfect matching
  (paper §5), ``(n, n)`` or ``(B, n, n)``.
* ``match_bipartite`` / ``match_bipartite_batch`` — maximum-cardinality
  bipartite matching via lock-free BFS augmenting-path phases
  (``repro.core.matching``; Deveci et al., arXiv:1303.1379).
* ``SolverKind`` / ``register_kind`` / ``get_kind`` / ``registered_kinds``
  — the solver-kind registry (``repro.core.kinds``): the one seam the
  batch front end, the serving engines, and the benchmark runner dispatch
  through; register a kind once and every layer above serves it
  (docs/solvers.md).
* ``solve_batch`` / ``prepare_buckets`` / ``solve_prepared`` — the generic
  pad-and-bucket front end for ragged collections of ANY registered kind
  (``repro.core.batch``); ``solve_maxflow_batch`` /
  ``solve_assignment_batch`` are its historical per-kind spellings.
* ``freeze`` — the per-instance liveness select behind batched solving
  (``repro.core.masking``).
* ``LoopSpec`` / ``run_masked`` / ``run_compacted`` / ``cycle_events`` /
  ``CycleEvent`` / ``trace_cycles`` — the unified solver-loop runtime
  (``repro.core.solver_loop``): masked iteration, early-exit compaction,
  and the structured per-cycle telemetry stream both drivers emit
  (``trace_cycles`` is the legacy (cycle, n_live) shim), shared by every
  kind.
* ``PreparedBucket`` / ``BucketStats`` — the host-stage hand-off and the
  per-dispatch occupancy/round-spread telemetry (``stats_out=`` on the
  batch front ends; the signal behind ``repro.serve.scheduler``'s
  adaptive dispatch).

Every entry point accepts ``mesh=`` (device-mesh batch sharding) and the
batched ones ``compact=`` (early-exit compaction); see docs/batching.md.
"""
from repro.core.assignment.cost_scaling import (AssignmentResult,
                                               solve_assignment)
from repro.core.batch import (BucketStats, PreparedBucket, prepare_buckets,
                              solve_assignment_batch, solve_batch,
                              solve_maxflow_batch, solve_prepared)
from repro.core.kinds import (SolverKind, get_kind, register_kind,
                              registered_kinds)
from repro.core.masking import freeze
from repro.core.matching import (MatchingResult, match_bipartite,
                                 match_bipartite_batch)
from repro.core.maxflow.grid import (GridFlowResult, GridProblem,
                                     maxflow_grid, maxflow_grid_batch)
from repro.core.solver_loop import (CycleEvent, LoopSpec, cycle_events,
                                    run_compacted, run_masked, trace_cycles)

__all__ = [
    "AssignmentResult",
    "BucketStats",
    "CycleEvent",
    "GridFlowResult",
    "GridProblem",
    "LoopSpec",
    "MatchingResult",
    "PreparedBucket",
    "SolverKind",
    "cycle_events",
    "freeze",
    "get_kind",
    "match_bipartite",
    "match_bipartite_batch",
    "maxflow_grid",
    "maxflow_grid_batch",
    "prepare_buckets",
    "register_kind",
    "registered_kinds",
    "run_compacted",
    "run_masked",
    "solve_assignment",
    "solve_assignment_batch",
    "solve_batch",
    "solve_maxflow_batch",
    "solve_prepared",
    "trace_cycles",
]
