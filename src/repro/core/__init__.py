"""Public surface of the solver core (the paper's two algorithms + engine).

The paper's primary contribution — synchronous data-parallel flow and
matching solvers — lives here:

* ``maxflow_grid`` / ``maxflow_grid_batch`` — push-relabel max-flow /
  min-cut on 2-D grid graphs (paper §4), single instance or ``(B, 4, H, W)``
  stacks with per-instance convergence.
* ``solve_assignment`` — cost-scaling max-weight perfect matching
  (paper §5), ``(n, n)`` or ``(B, n, n)``.
* ``solve_maxflow_batch`` / ``solve_assignment_batch`` — the pad-and-bucket
  front end for ragged collections (``repro.core.batch``).
* ``freeze`` — the per-instance liveness select behind batched solving
  (``repro.core.masking``).
* ``LoopSpec`` / ``run_masked`` / ``run_compacted`` / ``trace_cycles`` —
  the unified solver-loop runtime (``repro.core.solver_loop``): masked
  iteration, early-exit compaction, and the per-cycle live-count trace
  hook, shared by both solvers.
* ``BucketStats`` — per-dispatch occupancy/round-spread telemetry
  (``stats_out=`` on the batch front ends; the signal behind
  ``repro.serve.scheduler``'s adaptive dispatch).

Every entry point accepts ``mesh=`` (device-mesh batch sharding) and the
batched ones ``compact=`` (early-exit compaction); see docs/batching.md.
"""
from repro.core.assignment.cost_scaling import (AssignmentResult,
                                               solve_assignment)
from repro.core.batch import (BucketStats, solve_assignment_batch,
                              solve_maxflow_batch)
from repro.core.masking import freeze
from repro.core.maxflow.grid import (GridFlowResult, GridProblem,
                                     maxflow_grid, maxflow_grid_batch)
from repro.core.solver_loop import (LoopSpec, run_compacted, run_masked,
                                    trace_cycles)

__all__ = [
    "AssignmentResult",
    "BucketStats",
    "GridFlowResult",
    "GridProblem",
    "LoopSpec",
    "freeze",
    "maxflow_grid",
    "maxflow_grid_batch",
    "run_compacted",
    "run_masked",
    "solve_assignment",
    "solve_assignment_batch",
    "solve_maxflow_batch",
    "trace_cycles",
]
