"""Incremental re-solve: graph deltas, a solution cache, and the generic
warm-start driver over the SolverKind warm seam.

Production graphs mutate and re-ask (ROADMAP: streaming video cuts, live
marketplace matching, road networks); "Scalable Maxflow Processing for
Dynamic Graphs" (arXiv 2511.01235) shows restarting push-relabel from the
previous preflow/labels after capacity deltas beats from-scratch solves by
large factors, and Baumstark et al. (arXiv 1507.01926) show a valid height
function is the only invariant the restart needs.  This module is the
kind-agnostic half of that pipeline:

* ``GraphDelta`` — a sparse edit (set-semantics) against a validated
  payload: ``apply_delta`` materializes the mutated payload and
  re-validates it, so a delta can never smuggle a malformed problem past
  the submit-time contract.
* ``WarmStart`` — what a warm instance carries into a solve: the cached
  prior ``solution`` (the kind's ``solution_of`` artifact), optionally the
  ``base_problem`` it solved (kinds that reconstruct flows from residuals
  need it) and a precomputed ``delta_bound``.
* ``SolutionCache`` — content-hash keyed (graph identity = bytes of the
  validated payload, not object identity), LRU with entry- and byte-
  budgets; evicted entries spill through ``repro.checkpoint.store.put`` /
  ``get`` and are transparently reloaded on hit.
* ``solve_warm`` — the generic driver: pads warm and cold instances into
  the SAME buckets, builds per-instance states through the kind's
  ``init_state`` / ``warm_state`` hooks, and drives the UNCHANGED masked /
  compacted / sharded-lane loop runtimes from that state.  The correctness
  contract (tests/test_warm.py): a warm-started solve reaches the same
  optimum as a cold solve of the mutated graph, for every kind and driver.
"""
from __future__ import annotations

import functools
import hashlib
import threading
from collections import OrderedDict
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kinds import get_kind
from repro.core.solver_loop import run_masked

__all__ = [
    "GraphDelta", "apply_delta", "WarmStart", "SolutionCache",
    "content_key", "delta_bound", "solve_warm",
]


class GraphDelta(NamedTuple):
    """One sparse edit against a validated payload (SET semantics).

    ``field`` selects a payload component by attribute name for structured
    payloads (``"cap_nbr"`` / ``"cap_src"`` / ``"cap_sink"`` on a maxflow
    ``GridProblem``); ``None`` addresses the payload itself when it is a
    single array (the assignment weight matrix, the dense matching
    adjacency).  ``idx`` is a tuple of integer index arrays, one per axis
    of the addressed array (numpy advanced indexing); ``values`` are the
    new entries written at those positions.  Deltas never change shape —
    a warm re-solve is the same graph with different capacities/weights.
    """

    idx: tuple
    values: Any
    field: str | None = None


def apply_delta(kind: str, payload, delta) -> Any:
    """Apply one ``GraphDelta`` (or a sequence) to ``payload``; returns the
    mutated, RE-VALIDATED payload.  The input payload is never aliased."""
    k = get_kind(kind)
    out = k.validate(payload)
    deltas = [delta] if isinstance(delta, GraphDelta) else list(delta)
    for d in deltas:
        if not isinstance(d, GraphDelta):
            raise TypeError(f"expected GraphDelta, got {type(d).__name__}")
        if d.field is None:
            arr = np.array(out, copy=True)
            arr[tuple(np.asarray(i) for i in d.idx)] = d.values
            out = arr
        else:
            if not hasattr(out, d.field):
                raise ValueError(
                    f"{kind!r} payload has no field {d.field!r} "
                    f"(fields: {getattr(out, '_fields', ())})")
            arr = np.array(getattr(out, d.field), copy=True)
            arr[tuple(np.asarray(i) for i in d.idx)] = d.values
            out = out._replace(**{d.field: arr})
    return k.validate(out)


class WarmStart(NamedTuple):
    """Warm-start directive for one instance (see module docstring).

    ``delta_bound`` — an upper bound on the largest per-entry change
    between ``base_problem`` and the instance's (mutated) payload; kinds
    use it to pick how much of their schedule the warm start may skip
    (the assignment ε ladder).  ``None`` means "compute it from
    ``base_problem``, or be conservative".
    """

    solution: Any
    base_problem: Any = None
    delta_bound: float | None = None


def content_key(kind: str, payload) -> str:
    """Content-hash graph identity of a VALIDATED payload.

    Two payloads with equal leaf bytes (dtype, shape, values) get the same
    key regardless of object identity or array backend — the cache key for
    ``SolutionCache`` and the spill key for ``checkpoint.store.put``.
    """
    h = hashlib.sha256(kind.encode())
    for leaf in jax.tree.leaves(payload):
        a = np.ascontiguousarray(np.asarray(jax.device_get(leaf)))
        h.update(str(a.dtype).encode())
        h.update(repr(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def delta_bound(new_payload, base_payload) -> float:
    """Max per-entry absolute change between two same-shape payloads."""
    bound = 0.0
    new_leaves = jax.tree.leaves(new_payload)
    base_leaves = jax.tree.leaves(base_payload)
    if len(new_leaves) != len(base_leaves):
        raise ValueError("payloads differ in structure; no delta bound")
    for n, b in zip(new_leaves, base_leaves):
        na = np.asarray(jax.device_get(n)).astype(np.float64)
        ba = np.asarray(jax.device_get(b)).astype(np.float64)
        if na.shape != ba.shape:
            raise ValueError(
                f"payload leaves differ in shape ({na.shape} vs {ba.shape}); "
                f"deltas never change shape")
        if na.size:
            bound = max(bound, float(np.max(np.abs(na - ba))))
    return bound


class _Entry(NamedTuple):
    kind: str
    problem: Any      # the validated payload the solution solves
    solution: Any     # the kind's solution_of artifact
    nbytes: int


def _tree_nbytes(tree) -> int:
    return int(sum(np.asarray(jax.device_get(l)).nbytes
                   for l in jax.tree.leaves(tree)))


class SolutionCache:
    """LRU solution cache keyed by content-hash graph identity.

    Budgets: at most ``max_entries`` entries and ``max_bytes`` total leaf
    bytes in memory; the least-recently-used entries beyond either budget
    are dropped — or, with ``spill_dir``, persisted through
    ``repro.checkpoint.store.put`` and transparently reloaded (and
    re-promoted to memory) when hit again.  ``hits``/``misses`` count
    ``get`` outcomes; the serving metrics surface reads them.
    """

    def __init__(self, *, max_entries: int = 128,
                 max_bytes: int = 64 << 20, spill_dir: str | None = None):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = int(max_entries)
        self.max_bytes = int(max_bytes)
        self.spill_dir = spill_dir
        self._mem: OrderedDict[str, _Entry] = OrderedDict()
        # spilled entries keep their STRUCTURE here (treedefs aren't
        # serializable); leaves live on disk under the same key
        self._spilled: dict[str, tuple] = {}
        # serving drives one shared cache from several lane threads
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem) + len(self._spilled)

    @property
    def nbytes(self) -> int:
        with self._lock:
            return sum(e.nbytes for e in self._mem.values())

    def key(self, kind: str, payload) -> str:
        return content_key(kind, get_kind(kind).validate(payload))

    def put(self, kind: str, payload, solution) -> str:
        """Cache ``solution`` for the validated ``payload``; returns key."""
        problem = get_kind(kind).validate(payload)
        key = content_key(kind, problem)
        entry = _Entry(kind=kind, problem=problem, solution=solution,
                       nbytes=_tree_nbytes(problem) + _tree_nbytes(solution))
        with self._lock:
            self._spilled.pop(key, None)
            self._mem[key] = entry
            self._mem.move_to_end(key)
            self._shrink()
        return key

    def get(self, key: str) -> _Entry | None:
        """Entry for ``key`` (memory or spill), ``None`` + a miss if absent."""
        with self._lock:
            entry = self._mem.get(key)
            if entry is not None:
                self._mem.move_to_end(key)
                self.hits += 1
                return entry
            entry = self._unspill(key)
            if entry is not None:
                self.hits += 1
                return entry
            self.misses += 1
            return None

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._mem), "spilled": len(self._spilled),
                "nbytes": sum(e.nbytes for e in self._mem.values()),
                "hits": self.hits, "misses": self.misses,
                "hit_rate": (self.hits / total) if total else None,
            }

    def _shrink(self) -> None:
        while (len(self._mem) > self.max_entries
               or self.nbytes > self.max_bytes):
            if len(self._mem) == 1 and len(self._mem) <= self.max_entries:
                break                       # never evict the sole entry
            key, entry = self._mem.popitem(last=False)
            self._spill(key, entry)

    def _spill(self, key: str, entry: _Entry) -> None:
        if self.spill_dir is None:
            return                          # plain eviction
        from repro.checkpoint import store
        p_leaves, p_def = jax.tree.flatten(entry.problem)
        s_leaves, s_def = jax.tree.flatten(entry.solution)
        store.put(self.spill_dir, key, list(p_leaves) + list(s_leaves))
        self._spilled[key] = (entry.kind, p_def, s_def, len(p_leaves),
                              entry.nbytes)

    def _unspill(self, key: str) -> _Entry | None:
        meta = self._spilled.get(key)
        if meta is None:
            return None
        from repro.checkpoint import store
        leaves = store.get(self.spill_dir, key)
        if leaves is None:                  # spill file vanished
            del self._spilled[key]
            return None
        kind, p_def, s_def, n_p, nbytes = meta
        entry = _Entry(kind=kind,
                       problem=jax.tree.unflatten(p_def, leaves[:n_p]),
                       solution=jax.tree.unflatten(s_def, leaves[n_p:]),
                       nbytes=nbytes)
        del self._spilled[key]
        self._mem[key] = entry              # promote back to memory
        self._shrink()
        return entry


# --------------------------------------------------------------- the driver


def _lead_axis(spec, leaf, batch_ndim: int = 1) -> int:
    fn = getattr(spec, "lead_axes_fn", None)
    return fn(leaf, batch_ndim) if fn is not None else 0


def _concat_states(spec, states1: list):
    """Concatenate batch-1 states along each leaf's batch axis."""
    if len(states1) == 1:
        return states1[0]

    def cat(*xs):
        return jnp.concatenate(xs, axis=_lead_axis(spec, xs[0]))

    return jax.tree.map(cat, *states1)


@functools.partial(jax.jit, static_argnames=("spec", "n"))
def _run_masked_state(spec, state, n: int):
    return run_masked(spec, state, (n,))


def build_warm_state(kind_obj, rt, warm_fn, problem1, payload, ws, bshape):
    """One warm instance's state: resolve the base, bound the delta, call
    the kind's ``warm_state`` hook.  Shared by ``solve_warm`` and the
    refill session's warm admissions."""
    base1, bound = None, ws.delta_bound
    if ws.base_problem is not None:
        base = kind_obj.validate(ws.base_problem)
        if bound is None:
            bound = delta_bound(payload, base)
        base1 = rt.pad_one(base, bshape)
    return warm_fn(problem1, ws.solution, base_problem1=base1,
                   delta_bound=bound)


def solve_warm(kind: str, payloads: Sequence, warm: dict | None = None, *,
               bucket: str = "max", compact: bool = False, mesh=None,
               mesh_axis: str | None = None, stats_out: list | None = None,
               **solver_kw) -> list:
    """Solve ``payloads`` with per-instance warm starts mixed into the
    ordinary cold buckets; returns per-payload results in input order.

    ``warm`` maps payload positions to ``WarmStart``s; positions absent
    from it are cold-initialized through the kind's registered
    ``init_state`` hook — inside the SAME bucket, so a mixed batch costs
    one dispatch.  Drivers: the jitted masked loop by default,
    ``run_compacted`` under ``compact=True``, per-device compacted lanes
    when ``mesh`` is given.  ``stats_out`` (a list) receives one
    ``BucketStats`` per dispatched bucket, exactly like
    ``repro.core.batch.solve_batch``.
    """
    from repro.core.batch import BucketStats, _bucket_shape
    from repro.core.solver_loop import _tree_take, run_compacted

    k = get_kind(kind)
    for hook in ("refill", "init_state", "warm_state"):
        if getattr(k, hook) is None:
            raise ValueError(
                f"solver kind {kind!r} registered no {hook!r} hook; it "
                f"cannot warm-start (serve it cold through solve_batch)")
    warm = dict(warm or {})
    for pos in warm:
        if not 0 <= pos < len(payloads):
            raise ValueError(
                f"warm position {pos} out of range for "
                f"{len(payloads)} payloads")
        if not isinstance(warm[pos], WarmStart):
            raise TypeError(
                f"warm[{pos}] must be a WarmStart, "
                f"got {type(warm[pos]).__name__}")

    rt = k.refill(**solver_kw)
    init_fn = k.init_state(**solver_kw)
    warm_fn = k.warm_state(**solver_kw)
    validated = [k.validate(p) for p in payloads]
    shapes = [rt.shape_of(p) for p in validated]
    if not validated:
        return []

    # group positions by bucket shape — warm and cold share buckets
    ndim = len(shapes[0])
    max_shape = tuple(max(s[d] for s in shapes) for d in range(ndim))
    groups: dict[tuple, list[int]] = {}
    for i, s in enumerate(shapes):
        groups.setdefault(_bucket_shape(s, bucket, max_shape), []).append(i)

    results: dict[int, Any] = {}
    for bshape, idxs in groups.items():
        problems1 = {i: rt.pad_one(validated[i], bshape) for i in idxs}
        states1 = []
        for i in idxs:
            if i in warm:
                states1.append(build_warm_state(
                    k, rt, warm_fn, problems1[i], validated[i], warm[i],
                    bshape))
            else:
                states1.append(init_fn(problems1[i]))
        n = len(idxs)
        if mesh is not None:
            # pad with inert instances so the batch divides the shard
            # count, exactly like solve_batch's mesh path
            from repro.launch.mesh import compact_lanes, shard_count
            n_pad = -n % shard_count(mesh, mesh_axis)
            for _ in range(n_pad):
                states1.append(
                    init_fn(rt.pad_one(k.inert_problem(bshape), bshape)))
            state = _concat_states(rt.spec, states1)
            state, rounds = run_compacted(
                rt.spec, state, n + n_pad,
                lanes=compact_lanes(mesh, mesh_axis, n + n_pad))
        elif compact:
            state = _concat_states(rt.spec, states1)
            state, rounds = run_compacted(rt.spec, state, n)
        else:
            state = _concat_states(rt.spec, states1)
            state, rounds = _run_masked_state(rt.spec, state, n)

        rounds = jnp.asarray(rounds)
        for b, i in enumerate(idxs):
            state1 = _tree_take(rt.spec, state, jnp.asarray([b]))
            res1 = rt.finalize(problems1[i], state1, rounds[b:b + 1])
            results[i] = rt.crop(res1, shapes[i], validated[i])
        if stats_out is not None:
            r = np.asarray(rounds)
            conv = sum(bool(np.asarray(results[i].converged)) for i in idxs
                       if hasattr(results[i], "converged"))
            stats_out.append(BucketStats(
                kind=kind, shape=bshape, n_real=n, n_pad=0,
                compact=bool(compact or mesh is not None),
                rounds_min=int(r.min()), rounds_max=int(r.max()),
                rounds_mean=float(r.mean()), n_converged=int(conv)))
    return [results[i] for i in range(len(payloads))]
