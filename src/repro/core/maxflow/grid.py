"""Synchronous data-parallel push-relabel max-flow on 2D grid graphs.

TPU adaptation of the paper's §4 (Hong's lock-free push-relabel, CUDA) — see
DESIGN.md §2. One Jacobi round applies the per-node decision of Algorithm 4.5
to EVERY node simultaneously:

  * each active node (e > 0) finds its lowest residual neighbour (sink at
    height 0, the four grid neighbours, source at height N),
  * if strictly lower, it pushes ``min(e, cap)`` toward it (Hong's relaxed
    rule: push whenever ``h(x) > h(ỹ)``, not only ``== h+1``),
  * otherwise it relabels to ``h(ỹ) + 1``.

Concurrent ``e(y) += δ`` updates (atomicAdd in the paper) become one shift-and-
add aggregation per round — associativity of addition replaces atomicity.
The global/gap relabeling heuristic (paper Alg. 4.4/4.8) is a vectorized
min-plus wavefront BFS from the sink run every ``rounds_per_heuristic`` rounds,
inside the same jitted while_loop (no host round-trip, unlike the CPU-GPU
hybrid of Hong & He).

Grid layout: ``cap[d, i, j]`` is the residual capacity of the edge from node
(i, j) toward its neighbour in direction d ∈ {UP, DOWN, LEFT, RIGHT}.
``cap_src``/``cap_sink`` are the residual capacities of the terminal edges
(x → s) and (x → t).

Batching: every helper here operates on the LAST two axes, so state arrays may
carry leading batch dimensions — ``e``: ``(..., H, W)``, ``cap``:
``(4, ..., H, W)`` (direction axis first so ``cap[d]`` stays a plain index).
``maxflow_grid`` solves one instance; ``maxflow_grid_batch`` solves a stack of
same-shape instances in ONE jitted dispatch, with per-instance convergence
masks so converged instances become no-ops instead of blocking the batch
(see ``repro.core.batch`` for the pad-and-bucket front end). Outer
orchestration is delegated to ``repro.core.solver_loop``: masked iteration
by default, early-exit compaction — converged instances leave the working
set between cycles — under ``compact=True``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.solver_loop import (LoopSpec, masked_events_active,
                                    run_compacted, run_masked)

UP, DOWN, LEFT, RIGHT = 0, 1, 2, 3
_OPP = (DOWN, UP, RIGHT, LEFT)
INF_H = jnp.int32(2 ** 30)


class GridProblem(NamedTuple):
    """A grid-cut instance (the Kolmogorov graph construction of [12])."""

    cap_nbr: jax.Array   # (4, H, W) neighbour capacities
    cap_src: jax.Array   # (H, W) capacity of s -> x
    cap_sink: jax.Array  # (H, W) capacity of x -> t


class GridFlowState(NamedTuple):
    e: jax.Array          # (..., H, W) excess
    h: jax.Array          # (..., H, W) heights, int32
    cap: jax.Array        # (4, ..., H, W) residual neighbour capacities
    cap_src: jax.Array    # (..., H, W) residual x -> s (returns excess)
    cap_sink: jax.Array   # (..., H, W) residual x -> t
    sink_flow: jax.Array  # (...,) total flow delivered to the sink
    src_flow: jax.Array   # (...,) total flow returned to the source
    # (...,) int32 count of global-relabel (heuristic) invocations per
    # instance, excluding the round-0 init BFS. None = untracked (states
    # built by hand, e.g. kernel unit tests); solver-built states always
    # carry it. None is an empty pytree subtree, so both forms jit.
    heur: jax.Array | None = None


class GridFlowResult(NamedTuple):
    flow: jax.Array        # (...,) max-flow value(s)
    cut: jax.Array         # (..., H, W) bool — True = sink side of the cut
    state: GridFlowState   # NOTE: maxflow_grid_batch returns cap (B, 4, H, W)
    rounds: jax.Array      # (...,) Jacobi rounds executed per instance
    converged: jax.Array   # (...,) bool
    # (...,) heuristic invocations (see GridFlowState.heur); None when the
    # state was solved by a pre-observability caller.
    heuristics: jax.Array | None = None


def _nbr_h(h: jax.Array, d: int) -> jax.Array:
    """Height of the neighbour in direction d, INF outside the grid.

    Operates on the last two (H, W) axes; leading batch axes pass through.
    """
    big = INF_H
    if d == UP:
        return jnp.concatenate(
            [jnp.full_like(h[..., :1, :], big), h[..., :-1, :]], axis=-2)
    if d == DOWN:
        return jnp.concatenate(
            [h[..., 1:, :], jnp.full_like(h[..., :1, :], big)], axis=-2)
    if d == LEFT:
        return jnp.concatenate(
            [jnp.full_like(h[..., :, :1], big), h[..., :, :-1]], axis=-1)
    return jnp.concatenate(
        [h[..., :, 1:], jnp.full_like(h[..., :, :1], big)], axis=-1)


def _move(a: jax.Array, d: int) -> jax.Array:
    """Deposit a[x] at x's neighbour in direction d (zero fill at border)."""
    z = jnp.zeros_like
    if d == UP:
        return jnp.concatenate([a[..., 1:, :], z(a[..., :1, :])], axis=-2)
    if d == DOWN:
        return jnp.concatenate([z(a[..., :1, :]), a[..., :-1, :]], axis=-2)
    if d == LEFT:
        return jnp.concatenate([a[..., :, 1:], z(a[..., :, :1])], axis=-1)
    return jnp.concatenate([z(a[..., :, :1]), a[..., :, :-1]], axis=-1)


def _gsum(a: jax.Array) -> jax.Array:
    """Per-instance grid sum: reduce the trailing (H, W) axes only."""
    return jnp.sum(a, axis=(-2, -1))


def jacobi_round(state: GridFlowState, n_nodes: jax.Array) -> GridFlowState:
    """One synchronous push/relabel round over every node (Alg. 4.5, Jacobi).

    Shape-polymorphic over leading batch axes: ``e`` may be ``(..., H, W)``
    with ``cap`` ``(4, ..., H, W)``; a converged instance (no active node) is
    an exact no-op, which is what makes the batched solver sound.
    """
    e, h, cap, cap_src, cap_sink, sink_flow, src_flow = state[:7]
    active = e > 0

    # Candidate heights: [sink, source, UP, DOWN, LEFT, RIGHT]; INF if the
    # corresponding residual edge is absent. argmin picks the first minimum,
    # so the sink (height 0) always wins when available, and ties at height N
    # prefer the source (stranded excess drains home instead of bouncing).
    cand = jnp.stack(
        [jnp.where(cap_sink > 0, 0, INF_H),
         jnp.where(cap_src > 0, n_nodes, INF_H)]
        + [jnp.where(cap[d] > 0, _nbr_h(h, d), INF_H) for d in range(4)],
        axis=0,
    )  # (6, ..., H, W)
    h_min = jnp.min(cand, axis=0)
    choice = jnp.argmin(cand, axis=0)

    do_push = active & (h > h_min)
    do_relabel = active & (h <= h_min) & (h_min < INF_H)

    # --- relabel (needs no atomicity: only x writes h(x); paper line 17) ---
    h_new = jnp.where(do_relabel, h_min + 1, h)

    # --- push (fulfillment stages aggregated by shift-adds) ---
    cap_choice = jnp.stack([cap_sink, cap_src] + [cap[d] for d in range(4)], 0)
    delta_all = jnp.where(do_push, jnp.minimum(e, jnp.take_along_axis(
        cap_choice, choice[None], axis=0)[0]), 0.0)

    d_sink = jnp.where(choice == 0, delta_all, 0.0)
    d_src = jnp.where(choice == 1, delta_all, 0.0)
    d_nbr = [jnp.where(choice == 2 + d, delta_all, 0.0) for d in range(4)]

    out = d_sink + d_src + sum(d_nbr)
    inflow = sum(_move(d_nbr[d], d) for d in range(4))

    e_new = e - out + inflow
    cap_new = jnp.stack(
        [cap[d] - d_nbr[d] + _move(d_nbr[_OPP[d]], _OPP[d]) for d in range(4)], 0
    )
    return state._replace(
        e=e_new,
        h=h_new,
        cap=cap_new,
        cap_src=cap_src - d_src,
        cap_sink=cap_sink - d_sink,
        sink_flow=sink_flow + _gsum(d_sink),
        src_flow=src_flow + _gsum(d_src),
    )


def jacobi_round_multipush(state: GridFlowState,
                           n_nodes: jax.Array) -> GridFlowState:
    """Beyond-paper round: push to EVERY strictly-lower residual neighbour.

    The paper's Algorithm 4.5 moves one unit-direction per node per round;
    saturating all admissible edges per round (priority: sink, source, then
    the grid directions) drains excess in fewer rounds at identical
    per-round cost on the VPU (every push is still admissible under Hong's
    relaxed rule against pre-round heights, so correctness is inherited).
    """
    e, h, cap, cap_src, cap_sink, sink_flow, src_flow = state[:7]
    active = e > 0

    cand_h = [jnp.where(cap_sink > 0, 0, INF_H),
              jnp.where(cap_src > 0, n_nodes, INF_H)] + \
             [jnp.where(cap[d] > 0, _nbr_h(h, d), INF_H) for d in range(4)]
    cand_cap = [cap_sink, cap_src] + [cap[d] for d in range(4)]

    remaining = jnp.where(active, e, 0.0)
    deltas = []
    pushed_any = jnp.zeros_like(active)
    for ch, cc in zip(cand_h, cand_cap):
        ok = active & (h > ch)
        d = jnp.where(ok, jnp.minimum(remaining, cc), 0.0)
        remaining = remaining - d
        pushed_any = pushed_any | (d > 0)
        deltas.append(d)
    d_sink, d_src, d_nbr = deltas[0], deltas[1], deltas[2:]

    # relabel only nodes that could not push anywhere
    h_min = jnp.minimum(jnp.minimum(cand_h[0], cand_h[1]),
                        jnp.minimum(jnp.minimum(cand_h[2], cand_h[3]),
                                    jnp.minimum(cand_h[4], cand_h[5])))
    do_relabel = active & ~pushed_any & (h <= h_min) & (h_min < INF_H)
    h_new = jnp.where(do_relabel, h_min + 1, h)

    out = d_sink + d_src + sum(d_nbr)
    inflow = sum(_move(d_nbr[d], d) for d in range(4))
    cap_new = jnp.stack(
        [cap[d] - d_nbr[d] + _move(d_nbr[_OPP[d]], _OPP[d]) for d in range(4)],
        0)
    return state._replace(
        e=e - out + inflow, h=h_new, cap=cap_new,
        cap_src=cap_src - d_src, cap_sink=cap_sink - d_sink,
        sink_flow=sink_flow + _gsum(d_sink),
        src_flow=src_flow + _gsum(d_src),
    )


def bfs_heights(cap: jax.Array, cap_sink: jax.Array, h_prev: jax.Array,
                n_nodes: jax.Array, max_iters: int) -> jax.Array:
    """Vectorized backwards BFS from the sink (paper Alg. 4.4 + gap relabel).

    Min-plus wavefront: h(x) = 1 if residual x->t, else 1 + min over residual
    out-edges (x, y) of h(y). Unreached nodes (the 'gap') get height >= N so
    the flow stranded on them returns to the source (paper §4.6). We keep
    ``max(h_prev, N)`` rather than the paper's plain ``N`` so heights already
    climbing toward the source (up to 2N-1) are never reset — resetting would
    let stranded excess oscillate between heuristic invocations.
    """
    h0 = jnp.where(cap_sink > 0, jnp.int32(1), INF_H)

    def body(carry):
        h, _, it = carry
        relaxed = h
        for d in range(4):
            cand = jnp.where(cap[d] > 0, _nbr_h(h, d) + 1, INF_H)
            relaxed = jnp.minimum(relaxed, cand)
        relaxed = jnp.minimum(relaxed, h0)
        changed = jnp.any(relaxed != h)
        return relaxed, changed, it + 1

    def cond(carry):
        _, changed, it = carry
        return changed & (it < max_iters)

    h, _, _ = jax.lax.while_loop(cond, body, (h0, jnp.bool_(True), jnp.int32(0)))
    return jnp.where(h >= INF_H, jnp.maximum(h_prev, n_nodes), h)  # gap relabel


def check_no_violations(state: GridFlowState) -> jax.Array:
    """True iff no residual edge (x,y) has h(x) > h(y)+1 (per instance).

    The paper's hybrid global relabel (Alg. 4.8 lines 1-6) cancels such
    violating edges, which arise under asynchronous interleaving. Our Jacobi
    schedule provably never creates them (DESIGN.md §2); this check is the
    runtime witness (asserted in tests / hypothesis properties). Returns a
    scalar for single instances, ``(B,)`` for batched states. Accepts both
    public layouts: ``maxflow_grid`` states (``cap`` ``(4, H, W)``) and
    ``maxflow_grid_batch`` results (``cap`` ``(B, 4, H, W)``).
    """
    cap = state.cap
    if state.h.ndim > 2:  # batched public layout -> internal (4, B, H, W)
        cap = jnp.moveaxis(cap, -3, 0)
    ok = jnp.ones(state.h.shape[:-2], jnp.bool_)
    for d in range(4):
        viol = (cap[d] > 0) & (state.h > _nbr_h(state.h, d) + 1)
        ok &= ~jnp.any(viol, axis=(-2, -1))
    return ok


VALID_BACKENDS = ("xla", "multipush", "pallas", "balanced")


def _round_fn(backend: str):
    """Jacobi-round implementation for a backend flag.

    Unknown strings raise (a typo'd backend silently solving with the
    default XLA round is a perf bug that looks like a perf result).
    """
    if backend == "pallas":  # the paper-optimized hot loop as a TPU kernel
        from repro.kernels.grid_push.ops import jacobi_round_pallas
        return jacobi_round_pallas
    if backend == "multipush":  # beyond-paper: saturate all lower nbrs
        return jacobi_round_multipush
    if backend == "balanced":  # active-tile scheduled kernel (drop the
        from repro.kernels.grid_push.ops import \
            jacobi_round_scheduled      # pushed-flow stall signal here)
        return lambda s, n: jacobi_round_scheduled(s, n)[0]
    if backend == "xla":
        return jacobi_round
    raise ValueError(
        f"unknown maxflow backend {backend!r}; valid backends: "
        f"{', '.join(VALID_BACKENDS)}")


@functools.lru_cache(maxsize=None)
def _grid_spec(rounds_per_heuristic: int, max_rounds: int,
               bfs_max_iters: int, backend: str,
               stall_threshold: float = 0.05) -> LoopSpec:
    """The grid solver's registration with the solver-loop runtime.

    Cached per static-knob tuple so repeated solves hand the runtime the
    SAME spec object and the compacted drivers' jitted cycles cache-hit.
    The cycle is shape-polymorphic: ``n_nodes`` and the BFS cap derive from
    the state's trailing (H, W), so one spec serves every grid size and
    every compaction sub-batch size.

    Every backend's cycle is exactly ``rounds_per_heuristic`` rounds (the
    runtime's rounds accounting assumes it). The fixed-cadence backends end
    the cycle with an unconditional global relabel; ``"balanced"`` ends it
    with a STALL-DRIVEN one — a per-instance EWMA of terminal-retired flow
    per unit remaining excess decides which instances re-run the (bidirectional)
    relabel pass, and ``lax.cond`` skips its cost entirely when no instance
    stalled. The trigger and the relabel are pure per-instance functions of
    per-instance state, so the batched == loop-of-singles bit-match
    contract survives (tests/test_balanced.py).
    """
    round_fn = _round_fn(backend)
    if backend == "balanced":
        from repro.kernels.bfs_relabel.ops import bfs_relabel_heights
        from repro.kernels.grid_push.ops import jacobi_round_scheduled

    def _count_heur(new: GridFlowState, invoked) -> GridFlowState:
        if new.heur is None:
            return new
        return new._replace(heur=new.heur + invoked.astype(jnp.int32))

    def cycle(state: GridFlowState) -> GridFlowState:
        H, W = state.e.shape[-2:]
        n_nodes = jnp.int32(H * W + 2)
        iters = bfs_max_iters or (H * W + 2)

        if backend == "balanced":
            batch = state.e.shape[:-2]

            def inner(_, carry):
                s, ewma = carry
                remaining = jnp.maximum(_gsum(s.e), 1.0)
                s, retired = jacobi_round_scheduled(s, n_nodes)
                # EWMA of per-round progress: excess RETIRED at a terminal
                # this round as a fraction of the excess still in flight
                # (inter-node moves don't count — height-plateau ping-pong
                # must read as a stall, not progress). Alpha 1/2 ≈ a
                # two-round memory — long enough to ride out single slack
                # rounds, short enough to catch a stall within a cycle.
                ewma = 0.5 * ewma + 0.5 * (retired / remaining)
                return s, ewma

            new, ewma = jax.lax.fori_loop(
                0, rounds_per_heuristic, inner,
                (state, jnp.ones(batch, jnp.float32)))
            stalled = (jnp.any(new.e > 0, axis=(-2, -1))
                       & (ewma < stall_threshold))

            def relabel(s: GridFlowState) -> jax.Array:
                h_bfs = bfs_relabel_heights(s.cap, s.cap_src, s.cap_sink,
                                            s.h, n_nodes, iters)
                return jnp.where(stalled[..., None, None], h_bfs, s.h)

            h_new = jax.lax.cond(jnp.any(stalled), relabel,
                                 lambda s: s.h, new)
            return _count_heur(new._replace(h=h_new), stalled)

        def inner(_, s):
            return round_fn(s, n_nodes)

        new = jax.lax.fori_loop(0, rounds_per_heuristic, inner, state)
        new = new._replace(
            h=bfs_heights(new.cap, new.cap_sink, new.h, n_nodes, iters))
        return _count_heur(new, jnp.ones(state.e.shape[:-2], jnp.bool_))

    def live(state: GridFlowState, rounds: jax.Array) -> jax.Array:
        return jnp.any(state.e > 0, axis=(-2, -1)) & (rounds < max_rounds)

    def lead_axes(a, batch_ndim: int) -> int:
        # the only leaf with an axis before the batch axes is cap
        # (4, ..., H, W) — the direction axis leads
        return 1 if a.ndim - batch_ndim == 3 else 0

    return LoopSpec(cycle=cycle, live=live,
                    rounds_per_cycle=rounds_per_heuristic,
                    lead_axes_fn=lead_axes,
                    heur=lambda s: s.heur)


def _grid_init(cap0, cs0, ct0, *, bfs_max_iters: int) -> GridFlowState:
    """Paper Alg. 4.7 init: saturate s->x, heights from a round-0 BFS.

    Internal layout — ``cs0``/``ct0`` ``(..., H, W)``, ``cap0``
    ``(4, ..., H, W)``.
    """
    *b, H, W = cs0.shape
    bshape = tuple(b)
    n_nodes = jnp.int32(H * W + 2)
    bfs_iters = bfs_max_iters or (H * W + 2)
    state = GridFlowState(
        e=cs0.astype(jnp.float32),
        h=jnp.zeros(bshape + (H, W), jnp.int32),
        cap=cap0.astype(jnp.float32),
        cap_src=cs0.astype(jnp.float32),   # residual x -> s after saturation
        cap_sink=ct0.astype(jnp.float32),
        sink_flow=jnp.zeros(bshape, jnp.float32),
        src_flow=jnp.zeros(bshape, jnp.float32),
        heur=jnp.zeros(bshape, jnp.int32),  # init BFS below not counted
    )
    # Start from BFS-consistent heights (global relabel at round 0).
    return state._replace(
        h=bfs_heights(state.cap, state.cap_sink, state.h, n_nodes, bfs_iters))


def _grid_finalize(state: GridFlowState, rounds, *,
                   bfs_max_iters: int) -> GridFlowResult:
    """Min cut + convergence flags from a finished (internal-layout) state.

    Sink side of the cut = nodes that still reach t in the residual graph.
    """
    H, W = state.e.shape[-2:]
    n_nodes = jnp.int32(H * W + 2)
    bfs_iters = bfs_max_iters or (H * W + 2)
    h_bfs = bfs_heights(state.cap, state.cap_sink, state.h, n_nodes, bfs_iters)
    return GridFlowResult(
        flow=state.sink_flow,
        cut=h_bfs < n_nodes,
        state=state,
        rounds=rounds,
        converged=~jnp.any(state.e > 0, axis=(-2, -1)),
        heuristics=state.heur,
    )


def _solve_grid(cap0, cs0, ct0, *, rounds_per_heuristic, max_rounds,
                bfs_max_iters, backend,
                stall_threshold=0.05) -> GridFlowResult:
    """Shared masked solver loop, rank-polymorphic over leading batch axes.

    ``cs0``/``ct0`` are ``(..., H, W)`` with ``cap0`` ``(4, ..., H, W)``.
    Orchestration lives in ``repro.core.solver_loop.run_masked``: the loop
    predicate is a per-instance liveness mask (batch shape ``(...,)``,
    scalar for a single instance) and converged instances are frozen via
    selects. With no batch axes the mask is the scalar predicate of the
    original single-instance loop, so both entry points share one
    trajectory.
    """
    state = _grid_init(cap0, cs0, ct0, bfs_max_iters=bfs_max_iters)
    spec = _grid_spec(rounds_per_heuristic, max_rounds, bfs_max_iters,
                      backend, stall_threshold)
    state, rounds = run_masked(spec, state, cs0.shape[:-2])
    return _grid_finalize(state, rounds, bfs_max_iters=bfs_max_iters)


_grid_init_jit = jax.jit(_grid_init, static_argnames=("bfs_max_iters",))
_grid_finalize_jit = jax.jit(_grid_finalize,
                             static_argnames=("bfs_max_iters",))


def _grid_warm(cap0, cs0, ct0, base_cap, base_ct, prior_cap, prior_ct,
               *, bfs_max_iters: int) -> GridFlowState:
    """Warm restart (arXiv 2511.01235 §3): clamp the prior flow to the new
    capacities, repair conservation deficits, re-BFS the heights.

    Internal layout throughout (``cap*`` ``(4, ..., H, W)``, rest
    ``(..., H, W)``).  ``base_*`` are the capacities the prior solve ran
    against; the prior NET flow per grid arc is recovered from its residuals
    as ``base_cap - prior_cap`` and per sink edge as ``base_ct - prior_ct``.
    The restart invariant (Baumstark et al., arXiv 1507.01926) is that the
    height function stays a valid lower bound on residual sink distance —
    guaranteed here by recomputing exact BFS heights against the repaired
    residual graph.  (Fresh zero gap memory, not the prior heights: exact
    distances plus a uniform ``N`` on the unreachable region can never
    contain a violating edge, whereas prior heights carried across a
    capacity delta can — the no-violations witness stays unconditional.)

    Repair: clamping to shrunken capacities can leave nodes with negative
    excess (more outflow than inflow).  A Jacobi fixpoint loop lets every
    deficit node cut its own outgoing flow (sink edge first, then the grid
    directions) until conservation holds with ``e >= 0`` everywhere; flows
    only ever decrease, so the loop terminates.  Any instance still in
    deficit at the iteration cap (unreachable for integral capacities, but
    cheap to guard) falls back to its cold init, keeping warm-vs-cold
    equivalence unconditional.
    """
    *b, H, W = cs0.shape
    n_nodes = jnp.int32(H * W + 2)
    bfs_iters = bfs_max_iters or (H * W + 2)
    capn = cap0.astype(jnp.float32)
    csn = cs0.astype(jnp.float32)
    ctn = ct0.astype(jnp.float32)

    # prior positive flow per arc, clamped to the new capacities
    f = base_cap.astype(jnp.float32) - prior_cap.astype(jnp.float32)
    phi = jnp.minimum(jnp.maximum(f, 0.0), capn)
    fs = jnp.clip(base_ct.astype(jnp.float32) - prior_ct.astype(jnp.float32),
                  0.0, ctn)

    def excess(phi, fs):
        # source saturates (cold-init convention): inflow from s is csn
        inflow = sum(_move(phi[d], d) for d in range(4))
        return csn + inflow - jnp.sum(phi, axis=0) - fs

    def body(carry):
        phi, fs, e, it = carry
        deficit = jnp.maximum(-e, 0.0)
        r = jnp.minimum(deficit, fs)
        fs = fs - r
        deficit = deficit - r
        rows = []
        for d in range(4):
            r = jnp.minimum(deficit, phi[d])
            rows.append(phi[d] - r)
            deficit = deficit - r
        phi = jnp.stack(rows, 0)
        return phi, fs, excess(phi, fs), it + 1

    def cond(carry):
        _, _, e, it = carry
        return jnp.any(e < 0) & (it < jnp.int32(4 * H * W + 8))

    phi, fs, e, _ = jax.lax.while_loop(
        cond, body, (phi, fs, excess(phi, fs), jnp.int32(0)))

    resid = jnp.stack(
        [capn[d] - phi[d] + _move(phi[_OPP[d]], _OPP[d]) for d in range(4)], 0)
    cap_sink = ctn - fs
    warm = GridFlowState(
        e=jnp.maximum(e, 0.0),
        h=bfs_heights(resid, cap_sink, jnp.zeros(csn.shape, jnp.int32),
                      n_nodes, bfs_iters),
        cap=resid,
        cap_src=csn,                       # residual x -> s after saturation
        cap_sink=cap_sink,
        sink_flow=_gsum(fs),
        src_flow=jnp.zeros(tuple(b), jnp.float32),
        heur=jnp.zeros(tuple(b), jnp.int32),
    )
    bad = jnp.any(e < 0, axis=(-2, -1))    # per-instance repair failure
    cold = _grid_init(cap0, cs0, ct0, bfs_max_iters=bfs_max_iters)

    def pick(w, c):
        extra = w.ndim - bad.ndim          # trailing (H, W) / leading (4,)
        mask = bad
        if w.ndim - len(b) == 3:           # cap leaf: leading direction axis
            mask = bad[None]
            extra -= 1
        return jnp.where(mask.reshape(mask.shape + (1,) * extra), c, w)

    return jax.tree.map(pick, warm, cold)


_grid_warm_jit = jax.jit(_grid_warm, static_argnames=("bfs_max_iters",))


def _grid_batch_compact(cap0, cs0, ct0, *, rounds_per_heuristic, max_rounds,
                        bfs_max_iters, backend, stall_threshold=0.05,
                        lanes=None) -> GridFlowResult:
    """Batched solve with early-exit compaction (public (B, ...) layout).

    ``run_compacted`` drives the host loop: still-live instances are
    gathered into dense pow2-sized sub-batches between jitted cycle
    segments, so converged instances stop consuming FLOPs instead of being
    select-masked until the whole batch drains. Results bit-match the
    masked path (tests/test_compact.py).
    """
    state = _grid_init_jit(jnp.moveaxis(jnp.asarray(cap0), 1, 0),
                           jnp.asarray(cs0), jnp.asarray(ct0),
                           bfs_max_iters=bfs_max_iters)
    spec = _grid_spec(rounds_per_heuristic, max_rounds, bfs_max_iters,
                      backend, stall_threshold)
    state, rounds = run_compacted(spec, state, cs0.shape[0], lanes=lanes)
    res = _grid_finalize_jit(state, rounds, bfs_max_iters=bfs_max_iters)
    # public layout: batch axis leads everywhere, including state.cap
    return res._replace(
        state=res.state._replace(cap=jnp.moveaxis(res.state.cap, 0, 1)))


def _grid_batch_stepped(cap0, cs0, ct0, *, rounds_per_heuristic, max_rounds,
                        bfs_max_iters, backend,
                        stall_threshold=0.05) -> GridFlowResult:
    """Eager masked solve for cycle telemetry (public (B, ...) layout).

    Same init/finalize jits as the compacted path around an eager
    ``run_masked`` call, which — under the active
    ``cycle_events(masked=True)`` hook that routed here — host-steps the
    jitted cycle and emits per-cycle events.  Bit-matches
    ``_grid_batch_impl`` (the per-cycle jit granularity is what the
    compacted driver already bit-matches at; tests/test_obs.py).
    """
    state = _grid_init_jit(jnp.moveaxis(jnp.asarray(cap0), 1, 0),
                           jnp.asarray(cs0), jnp.asarray(ct0),
                           bfs_max_iters=bfs_max_iters)
    spec = _grid_spec(rounds_per_heuristic, max_rounds, bfs_max_iters,
                      backend, stall_threshold)
    state, rounds = run_masked(spec, state, cs0.shape[:1])
    res = _grid_finalize_jit(state, rounds, bfs_max_iters=bfs_max_iters)
    return res._replace(
        state=res.state._replace(cap=jnp.moveaxis(res.state.cap, 0, 1)))


@functools.partial(
    jax.jit,
    static_argnames=("rounds_per_heuristic", "max_rounds", "bfs_max_iters",
                     "backend", "stall_threshold"),
)
def maxflow_grid(
    problem: GridProblem,
    *,
    rounds_per_heuristic: int = 32,
    max_rounds: int = 100_000,
    bfs_max_iters: int = 0,
    backend: str = "xla",
    stall_threshold: float = 0.05,
) -> GridFlowResult:
    """Max-flow / min-cut of ONE grid-cut instance (paper §4 on TPU).

    Args:
      problem: ``GridProblem`` with ``cap_nbr (4, H, W)``,
        ``cap_src``/``cap_sink`` ``(H, W)``. Integer-valued capacities are
        recommended (float32 sums over them stay exact, making results
        reproducible bit-for-bit across batching/sharding layouts).
      rounds_per_heuristic: Jacobi rounds between global-relabel BFS passes —
        the paper's CYCLE constant (§4.6, CYCLE=7000 on a GTX 560 Ti; far
        smaller here because our heuristic costs one on-device fixpoint, not
        a host round-trip).
      max_rounds: hard round cap; if hit, ``converged`` is False and
        ``flow``/``cut`` describe the partial state.
      bfs_max_iters: BFS wavefront cap (0 = the H*W+2 upper bound).
      backend: ``"xla"`` (paper-faithful Jacobi round), ``"multipush"``
        (beyond-paper: saturate every lower neighbour per round),
        ``"pallas"`` (the round's decision stage as a TPU kernel), or
        ``"balanced"`` (workload-balanced: active-tile-scheduled kernel
        dispatch, bidirectional BFS relabel kernel, stall-driven heuristic
        cadence — see docs/kernels.md). Unknown strings raise ValueError.
      stall_threshold: ``"balanced"`` only — the relabel pass runs when the
        EWMA of terminal-retired flow per unit remaining excess drops below
        this (0 = never relabel after init; the solver still terminates via
        +1 relabels).

    Returns:
      ``GridFlowResult``: scalar ``flow`` (== min-cut value when
      ``converged``), ``cut (H, W)`` bool (True = sink side of a minimum
      cut), the final ``GridFlowState``, scalar ``rounds`` and
      ``converged``, plus ``heuristics`` (global-relabel invocations).

    Convergence contract: ``converged`` is True iff no node holds positive
    excess, at which point ``flow`` is the exact max-flow value (the solver
    is exact, not approximate — termination follows the paper's §4
    potential argument).
    """
    cap0, cs0, ct0 = problem
    if cs0.ndim != 2 or cap0.ndim != 3:
        # A (B, 4, H, W) stack with B == 4 would silently alias the batch
        # axis onto the direction axis — reject batches loudly instead.
        raise ValueError(
            f"maxflow_grid solves ONE instance (cap_nbr (4, H, W), got "
            f"{cap0.shape}); use maxflow_grid_batch for stacked problems")
    return _solve_grid(cap0, cs0, ct0,
                       rounds_per_heuristic=rounds_per_heuristic,
                       max_rounds=max_rounds, bfs_max_iters=bfs_max_iters,
                       backend=backend, stall_threshold=stall_threshold)


@functools.partial(
    jax.jit,
    static_argnames=("rounds_per_heuristic", "max_rounds", "bfs_max_iters",
                     "backend", "stall_threshold"),
)
def _grid_batch_impl(cap0, cs0, ct0, *, rounds_per_heuristic, max_rounds,
                     bfs_max_iters, backend,
                     stall_threshold=0.05) -> GridFlowResult:
    """Batched solve in the public (B, ...) layout (shard_map-able body)."""
    res = _solve_grid(jnp.moveaxis(cap0, 1, 0), cs0, ct0,
                      rounds_per_heuristic=rounds_per_heuristic,
                      max_rounds=max_rounds, bfs_max_iters=bfs_max_iters,
                      backend=backend, stall_threshold=stall_threshold)
    # public layout: batch axis leads everywhere, including state.cap
    return res._replace(
        state=res.state._replace(cap=jnp.moveaxis(res.state.cap, 0, 1)))


def maxflow_grid_batch(
    problem: GridProblem,
    *,
    rounds_per_heuristic: int = 32,
    max_rounds: int = 100_000,
    bfs_max_iters: int = 0,
    backend: str = "xla",
    stall_threshold: float = 0.05,
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
) -> GridFlowResult:
    """Max-flow on a BATCH of same-shape grid instances in one dispatch.

    Args:
      problem: ``GridProblem`` with a leading batch axis — ``cap_nbr``
        ``(B, 4, H, W)`` (a plain stack of single-instance problems),
        ``cap_src``/``cap_sink`` ``(B, H, W)``.
      rounds_per_heuristic / max_rounds / bfs_max_iters / backend /
        stall_threshold: as in ``maxflow_grid`` (applied per instance).
      compact: early-exit compaction (``repro.core.solver_loop``). Instead
        of one jitted dispatch whose converged instances are select-masked
        until the whole batch drains, a host-driven loop gathers still-live
        instances into dense pow2-sized sub-batches between jitted cycle
        segments, so a converged instance stops consuming FLOPs. Worth it
        when convergence is ragged (stragglers dominate); the masked
        single-dispatch path wins when all instances finish together. With
        ``mesh=``, compaction stays WITHIN each shard (one host lane per
        device, no collectives — ``repro.launch.mesh.compact_lanes``).
      mesh: optional ``jax.sharding.Mesh`` (see
        ``repro.launch.mesh.make_solver_mesh``). When given, the batch axis
        is partitioned across the mesh under ``shard_map``: each device
        solves ``B // shard_count`` instances with NO cross-device
        communication (per-instance liveness masks make shards independent;
        a shard whose instances all converge finishes its dispatch early).
        ``B`` must be divisible by the shard count — the pad-and-bucket
        front end (``repro.core.batch``) pads ragged queues with inert
        instances instead of raising.
      mesh_axis: which mesh axis to shard over (default: the mesh's first
        axis, ``"batch"`` for solver meshes).

    Returns:
      ``GridFlowResult`` whose leaves lead with the batch axis:
      ``flow``/``rounds``/``converged`` are ``(B,)``, ``cut`` is
      ``(B, H, W)``, and ``state.cap`` is returned as ``(B, 4, H, W)``.

    Bit-match contract: runs the SAME shared cycle as ``maxflow_grid`` with
    batch shape ``(B,)`` — per-instance liveness masks (masked mode) or
    live-set gathers (compacted mode) advance exactly the instances still
    running, so results bit-match a loop of solo ``maxflow_grid`` runs,
    the sharded path bit-matches the unsharded one, and ``compact=True``
    bit-matches ``compact=False`` (an instance's trajectory never depends
    on its batch-mates; tests/test_batch.py, tests/test_shard.py,
    tests/test_compact.py).
    """
    cap0, cs0, ct0 = problem
    if cap0.ndim != 4 or cap0.shape[1] != 4 or cs0.ndim != 3:
        raise ValueError(
            f"maxflow_grid_batch expects cap_nbr (B, 4, H, W), got "
            f"{cap0.shape}; use maxflow_grid for a single instance")
    kw = dict(rounds_per_heuristic=rounds_per_heuristic,
              max_rounds=max_rounds, bfs_max_iters=bfs_max_iters,
              backend=backend, stall_threshold=stall_threshold)
    if compact:
        lanes = None
        if mesh is not None:
            from repro.launch.mesh import compact_lanes
            lanes = compact_lanes(mesh, mesh_axis, cs0.shape[0])
        return _grid_batch_compact(cap0, cs0, ct0, lanes=lanes, **kw)
    if mesh is None:
        if masked_events_active():
            return _grid_batch_stepped(cap0, cs0, ct0, **kw)
        return _grid_batch_impl(cap0, cs0, ct0, **kw)
    from repro.launch.mesh import dispatch_sharded
    return dispatch_sharded(_grid_batch_impl, (cap0, cs0, ct0),
                            cs0.shape[0], mesh, mesh_axis, **kw)
