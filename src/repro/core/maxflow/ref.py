"""Pure numpy/scipy reference oracles for grid max-flow (test-time only)."""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp
from scipy.sparse.csgraph import maximum_flow

UP, DOWN, LEFT, RIGHT = 0, 1, 2, 3


def random_grid_problem(rng: np.random.Generator, H: int, W: int,
                        max_cap: int = 10, terminal_density: float = 0.5):
    """Random integer grid-cut instance (terminal arcs randomly sparse)."""
    cap = rng.integers(0, max_cap + 1, size=(4, H, W)).astype(np.float32)
    # zero out off-grid directions so instances are well-formed
    cap[UP, 0, :] = 0
    cap[DOWN, -1, :] = 0
    cap[LEFT, :, 0] = 0
    cap[RIGHT, :, -1] = 0
    cs = rng.integers(0, max_cap + 1, size=(H, W)).astype(np.float32)
    ct = rng.integers(0, max_cap + 1, size=(H, W)).astype(np.float32)
    cs *= rng.random((H, W)) < terminal_density
    ct *= rng.random((H, W)) < terminal_density
    return cap, cs, ct


def long_path_problem(H: int, W: int, path_len: int = 0):
    """Adversarial: serpentine corridors that strand excess all along them.

    Each corridor is a boustrophedon path of ``path_len`` cells (default
    ``min(2·W, 128)``): the source feeds its head, only its tail reaches
    the sink, and the corridor edge out of cell k has capacity ``L-1-k``
    — strictly decreasing, so EVERY interior cell strands one unit of
    excess (max-flow is 1 per corridor). All that stranded flow must
    travel back to the source through the corridor's reverse residual
    arcs. The paper's flat gap-to-N relabel gives the return journey no
    gradient — stranded cells sit on a height plateau and creep home via
    +1 relabels and tie-broken pushes. The balanced backend's
    bidirectional relabel hands every cell its exact
    ``N + dist_to_source`` height in one pass, so all units march home
    simultaneously. Worst known family for ``backend="xla"`` rounds.

    Corridor LENGTH is the pathology scale and is held fixed as the grid
    grows (both backends pay the 2·L information-theoretic floor — flow
    must march out and stranded units must march home — so scaling L
    with the grid only dilutes the fixed-cadence overhead the family
    exists to expose). What scales with the grid instead is the corridor
    COUNT (one per 64-row band): larger instances have MORE thin active
    fronts in an ever-emptier grid, which is exactly the workload
    imbalance the active-tile schedule exploits.
    """
    if path_len <= 0:
        path_len = min(2 * W, 128)
    n_paths = max(1, H // 64)
    band = H // n_paths
    cap_nbr = np.zeros((4, H, W), np.float32)
    cs = np.zeros((H, W), np.float32)
    ct = np.zeros((H, W), np.float32)

    wc = min(W, 64)             # corridor column span: switchback geometry
    for m in range(n_paths):    # must not straighten out on wide grids
        r0 = m * band
        # boustrophedon walk within the band: left->right, right->left, ...
        cells = []
        for i in range(r0, min(r0 + band, H)):
            js = range(wc) if (i - r0) % 2 == 0 else range(wc - 1, -1, -1)
            cells.extend((i, j) for j in js)
        path = cells[:min(path_len, len(cells))]
        L = len(path)
        for k, ((i, j), (ii, jj)) in enumerate(zip(path, path[1:])):
            c = L - 1 - k
            if ii == i + 1:
                cap_nbr[DOWN, i, j] = c
                cap_nbr[UP, ii, jj] = c
            elif jj == j + 1:
                cap_nbr[RIGHT, i, j] = c
                cap_nbr[LEFT, ii, jj] = c
            else:
                cap_nbr[LEFT, i, j] = c
                cap_nbr[RIGHT, ii, jj] = c
        cs[path[0]] = L - 1 if L > 1 else 1
        ct[path[-1]] = 1        # the bottleneck: max-flow == 1 per corridor
    return cap_nbr, cs, ct


def checkerboard_problem(H: int, W: int, hi: int = 16, lo: int = 1):
    """Adversarial: alternating hi/lo capacity cells — a relabel stress.

    Source arcs on the left column, sink arcs on the right; neighbour
    capacities alternate ``hi``/``lo`` in a checkerboard, so flow
    repeatedly over-commits into hi-cells whose exits are lo-edges.
    Excess then oscillates on height plateaus until a relabel pass
    re-grades the landscape — frequent stalls, which is exactly what the
    balanced backend's stall trigger is for.
    """
    i, j = np.mgrid[0:H, 0:W]
    board = np.where((i + j) % 2 == 0, float(hi), float(lo))
    cap_nbr = np.zeros((4, H, W), np.float32)
    for d in range(4):
        cap_nbr[d] = board
    cap_nbr[UP, 0, :] = 0
    cap_nbr[DOWN, -1, :] = 0
    cap_nbr[LEFT, :, 0] = 0
    cap_nbr[RIGHT, :, -1] = 0
    cs = np.zeros((H, W), np.float32)
    ct = np.zeros((H, W), np.float32)
    cs[:, 0] = hi
    ct[:, -1] = lo
    return cap_nbr, cs, ct


def random_wide_problem(rng: np.random.Generator, H: int, W: int,
                        max_cap: int = 64):
    """Adversarial: heavy-tailed capacities, terminals on opposite edges.

    Unlike ``random_grid_problem`` (dense terminal arcs everywhere — short
    augmenting paths), all flow must cross the full grid width through
    capacities spanning two orders of magnitude, so the active frontier
    is wide and ragged: many rounds have most tiles idle, the active-tile
    schedule's best case.
    """
    cap = np.exp(rng.uniform(0, np.log(max_cap + 1), size=(4, H, W)))
    cap = np.floor(cap).astype(np.float32)
    cap[UP, 0, :] = 0
    cap[DOWN, -1, :] = 0
    cap[LEFT, :, 0] = 0
    cap[RIGHT, :, -1] = 0
    cs = np.zeros((H, W), np.float32)
    ct = np.zeros((H, W), np.float32)
    cs[:, 0] = np.floor(
        np.exp(rng.uniform(0, np.log(max_cap + 1), size=H))).astype(np.float32)
    ct[:, -1] = np.floor(
        np.exp(rng.uniform(0, np.log(max_cap + 1), size=H))).astype(np.float32)
    return cap, cs, ct


ADVERSARIAL_GENERATORS = {
    "long_path": lambda rng, H, W: long_path_problem(H, W),
    "checkerboard": lambda rng, H, W: checkerboard_problem(H, W),
    "random_wide": random_wide_problem,
}


def maxflow_grid_ref(cap_nbr: np.ndarray, cap_src: np.ndarray,
                     cap_sink: np.ndarray) -> int:
    """Exact max-flow value via scipy's Dinic (integer capacities)."""
    cap_nbr = np.asarray(cap_nbr)
    H, W = cap_src.shape
    n = H * W
    s, t = n, n + 1

    def nid(i, j):
        return i * W + j

    rows, cols, data = [], [], []
    for i in range(H):
        for j in range(W):
            x = nid(i, j)
            for d, (di, dj) in enumerate([(-1, 0), (1, 0), (0, -1), (0, 1)]):
                ii, jj = i + di, j + dj
                c = int(cap_nbr[d, i, j])
                if 0 <= ii < H and 0 <= jj < W and c > 0:
                    rows.append(x); cols.append(nid(ii, jj)); data.append(c)
            if cap_src[i, j] > 0:
                rows.append(s); cols.append(x); data.append(int(cap_src[i, j]))
            if cap_sink[i, j] > 0:
                rows.append(x); cols.append(t); data.append(int(cap_sink[i, j]))
    graph = sp.csr_matrix((data, (rows, cols)), shape=(n + 2, n + 2),
                          dtype=np.int64)
    return int(maximum_flow(graph, s, t).flow_value)
