"""Pure numpy/scipy reference oracles for grid max-flow (test-time only)."""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp
from scipy.sparse.csgraph import maximum_flow

UP, DOWN, LEFT, RIGHT = 0, 1, 2, 3


def random_grid_problem(rng: np.random.Generator, H: int, W: int,
                        max_cap: int = 10, terminal_density: float = 0.5):
    """Random integer grid-cut instance (terminal arcs randomly sparse)."""
    cap = rng.integers(0, max_cap + 1, size=(4, H, W)).astype(np.float32)
    # zero out off-grid directions so instances are well-formed
    cap[UP, 0, :] = 0
    cap[DOWN, -1, :] = 0
    cap[LEFT, :, 0] = 0
    cap[RIGHT, :, -1] = 0
    cs = rng.integers(0, max_cap + 1, size=(H, W)).astype(np.float32)
    ct = rng.integers(0, max_cap + 1, size=(H, W)).astype(np.float32)
    cs *= rng.random((H, W)) < terminal_density
    ct *= rng.random((H, W)) < terminal_density
    return cap, cs, ct


def maxflow_grid_ref(cap_nbr: np.ndarray, cap_src: np.ndarray,
                     cap_sink: np.ndarray) -> int:
    """Exact max-flow value via scipy's Dinic (integer capacities)."""
    cap_nbr = np.asarray(cap_nbr)
    H, W = cap_src.shape
    n = H * W
    s, t = n, n + 1

    def nid(i, j):
        return i * W + j

    rows, cols, data = [], [], []
    for i in range(H):
        for j in range(W):
            x = nid(i, j)
            for d, (di, dj) in enumerate([(-1, 0), (1, 0), (0, -1), (0, 1)]):
                ii, jj = i + di, j + dj
                c = int(cap_nbr[d, i, j])
                if 0 <= ii < H and 0 <= jj < W and c > 0:
                    rows.append(x); cols.append(nid(ii, jj)); data.append(c)
            if cap_src[i, j] > 0:
                rows.append(s); cols.append(x); data.append(int(cap_src[i, j]))
            if cap_sink[i, j] > 0:
                rows.append(x); cols.append(t); data.append(int(cap_sink[i, j]))
    graph = sp.csr_matrix((data, (rows, cols)), shape=(n + 2, n + 2),
                          dtype=np.int64)
    return int(maximum_flow(graph, s, t).flow_value)
