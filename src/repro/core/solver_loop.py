"""Unified solver-loop runtime: masked iteration + early-exit compaction.

Both paper solvers share one outer orchestration — a per-instance-masked
while-loop over "heuristic cycles" (a fixed number of Jacobi rounds plus a
vectorized heuristic pass).  A solver registers the pieces as a ``LoopSpec``:

* ``cycle(state) -> state`` — one heuristic cycle, batch-polymorphic and
  PER-INSTANCE PURE: instance ``b`` of the output depends only on instance
  ``b`` of the input (every reduction runs over trailing data axes; shared
  while-loop predicates inside, like a BFS fixpoint's ``changed``, may add
  no-op iterations but never change an instance's values),
* ``live(state, rounds) -> (...,) bool`` — the per-instance liveness mask,
* ``rounds_per_cycle`` — the per-instance round-accounting increment,
* ``lead_axes_fn(leaf, batch_ndim) -> int`` — how many leaf axes PRECEDE
  the batch axes (the freeze/gather/scatter spec; ``None`` = batch leads
  every leaf).

and the runtime owns the iteration in one of two modes:

* ``run_masked`` — the jittable baseline: every cycle computes the whole
  batch and ``freeze`` selects the old state back in for non-live
  instances.  A converged instance is an exact no-op — but still pays full
  FLOPs every cycle until the whole batch finishes.
* ``run_compacted`` — early-exit compaction (the ROADMAP item; cf. the
  active-set compaction of workload-balanced GPU push-relabel): a
  host-driven loop gathers still-live instances into dense pow2-sized
  sub-batches (fixed bucket sizes bound recompiles to <= log2(B) + 2 per
  solver config), runs the SAME jitted cycle on the compacted sub-batch,
  and scatters results back in input order.  Converged instances stop
  consuming FLOPs entirely instead of being select-masked forever.

Because cycles are per-instance pure, both modes execute the identical
per-instance trajectory: compacted results bit-match masked results, which
bit-match a loop of single-instance solves (tests/test_compact.py).

Sharding: ``run_compacted`` accepts per-shard LANES — contiguous batch
slices pinned to devices (``repro.launch.mesh.compact_lanes``).  Compaction
then happens within each lane only: instances never migrate between shards
and no collectives are introduced, preserving the shard-independence
contract of the mesh path.  Lane dispatches are issued before any liveness
mask is fetched, so devices run their cycles concurrently.

Continuous batching: ``run_compacted`` additionally accepts a REFILL hook
(``refill=``) — the cycle boundary where the host already re-gathers the
live set is also where a caller may inject NEW instances into slots
vacated by converged ones, instead of letting freed slots idle until the
whole batch drains (the admit-each-step structure of continuous-batching
LLM servers, applied to round-synchronous solvers).  Because admitted
instances enter with a fresh rounds counter and the cycles are
per-instance pure, a refilled run executes every instance's exact
solo-solve trajectory: values AND counters bit-match a loop of single
solves (tests/test_refill.py).  ``repro.core.refill`` wraps the hook
protocol into a per-kind session object.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masking import freeze

class CycleEvent(NamedTuple):
    """One structured per-cycle telemetry sample (``cycle_events``).

    Emitted BEFORE each cycle dispatches, by both drivers:

    * ``driver`` — ``"masked"`` or ``"compacted"``.
    * ``cycle`` — host cycle index, from 0.
    * ``n_live`` — still-live instances entering this cycle (all lanes).
    * ``rounds_total`` — sum of the per-slot rounds counters so far (with
      refill, counters describe current slot OCCUPANTS — admissions reset
      their slot, so treat this as a diagnostic, not a monotone total).
    * ``gathered`` — instances this cycle will actually compute: the
      padded pow2 sub-batch total for the compacted driver, the full
      batch size for the masked driver (its converged instances still pay
      FLOPs — exactly the waste ``gathered - n_live`` measures).
    * ``heur_total`` — sum of per-instance heuristic-invocation counters
      over the live set (the balanced backend's ``heuristics``), or
      ``None`` when the spec registers no ``heur`` extractor or the hook
      was installed without ``detail=True`` (fetching counters costs a
      device read per cycle, so it is opt-in).
    """

    driver: str
    cycle: int
    n_live: int
    rounds_total: int
    gathered: int
    heur_total: int | None


class _CycleHook(NamedTuple):
    fn: Callable          # CycleEvent -> None
    masked: bool          # also host-step run_masked to observe its cycles
    detail: bool          # fetch heur counters per cycle (a device read)


# Per-cycle telemetry hook. ``run_compacted`` fetches the live set every
# host cycle anyway, so emitting is nearly free there; ``run_masked`` is a
# jitted while_loop whose liveness never reaches the host — it emits only
# for hooks installed with ``masked=True``, by HOST-STEPPING the same
# jitted cycle (see ``run_masked``). Thread-local (a ContextVar) on
# purpose: the serving scheduler's lane threads trace their own
# dispatches without seeing each other's cycles — and the disabled cost
# is one contextvar read per solve.
_cycle_hook: contextvars.ContextVar["_CycleHook | None"] = \
    contextvars.ContextVar("solver_loop_cycle_hook", default=None)


@contextlib.contextmanager
def cycle_events(fn: Callable, *, masked: bool = False,
                 detail: bool = False):
    """Install ``fn(event: CycleEvent)`` as this thread's cycle hook.

    While active, every host cycle of ``run_compacted`` emits one
    ``CycleEvent`` (all lanes aggregated) BEFORE dispatching that cycle.
    With ``masked=True``, eager ``run_masked`` solves emit too: the
    driver host-steps its jitted cycle instead of lowering one fused
    while_loop — bit-identical results (the same per-cycle jit the
    compacted driver uses), at the cost of a host sync per cycle, so the
    serving scheduler's always-on metrics hook leaves it off.  With
    ``detail=True``, events include ``heur_total`` for specs that
    register a ``heur`` extractor (one extra device fetch per cycle).

    The hook must be cheap and must not raise.
    """
    token = _cycle_hook.set(_CycleHook(fn, masked, detail))
    try:
        yield
    finally:
        _cycle_hook.reset(token)


@contextlib.contextmanager
def trace_cycles(fn: Callable[[int, int], None]):
    """Back-compat shim over ``cycle_events``: ``fn(cycle_index, n_live)``.

    The original compaction-trace hook (``repro.serve.metrics`` records
    live-set decay through it). Equivalent to ``cycle_events`` with an
    adapter that drops every field but ``cycle`` and ``n_live``; masked
    solves do not emit (the pre-``CycleEvent`` behaviour).
    """
    with cycle_events(lambda ev: fn(ev.cycle, ev.n_live)):
        yield


def masked_events_active() -> bool:
    """Is a ``cycle_events(masked=True)`` hook installed on this thread?

    Solver batch wrappers consult this to route an eager masked solve
    through the host-stepped driver (init/finalize jits + per-cycle jit)
    instead of the fused jitted entry point, so the hook can observe
    per-cycle liveness. False for plain ``trace_cycles`` hooks.
    """
    hook = _cycle_hook.get()
    return hook is not None and hook.masked


class LoopSpec(NamedTuple):
    """A solver's registration with the loop runtime.

    Build specs through a cached factory (``functools.lru_cache`` keyed by
    the solver's static knobs) so repeated solves hand the runtime the SAME
    spec object — the jitted drivers use the spec as a static argument and
    cache compiled cycles per (spec, sub-batch shape).
    """

    cycle: Callable        # state -> state, one heuristic cycle (all-live)
    live: Callable         # (state, rounds) -> (...,) bool per instance
    rounds_per_cycle: int
    lead_axes_fn: Callable | None = None   # (leaf, batch_ndim) -> int
    # optional per-instance heuristic-invocation counters, state -> (...,)
    # int (the balanced backend's ``heuristics``); folded into CycleEvent
    # .heur_total for detail hooks
    heur: Callable | None = None


def _lead(spec: LoopSpec, batch_ndim: int):
    """Adapt the spec's (leaf, batch_ndim) signature to a (leaf,) closure."""
    if spec.lead_axes_fn is None:
        return None
    fn = spec.lead_axes_fn
    return lambda a: fn(a, batch_ndim)


def run_masked(spec: LoopSpec, state, batch_shape: tuple):
    """Masked iteration: cycle the whole batch, freeze non-live instances.

    Jittable (it is the body both jitted solver entry points trace).  With
    ``batch_shape == ()`` the mask is the scalar predicate of a
    single-instance loop — the freeze select is the identity while it runs —
    so single and batched solves share one trajectory.

    Telemetry: an EAGER call under a ``cycle_events(masked=True)`` hook
    host-steps the same body one jitted cycle at a time (``_masked_step``)
    so per-cycle liveness reaches the hook — bit-identical results, since
    the per-cycle jit is the granularity the compacted driver already
    bit-matches at.  Inside a trace (tracer leaves) the hook cannot apply
    and the fused while_loop is lowered as always — jit caches never
    depend on the hook.

    Returns ``(state, rounds)`` where ``rounds`` counts, per instance, the
    Jacobi rounds executed while that instance was live.
    """
    hook = _cycle_hook.get()
    if (hook is not None and hook.masked
            and not any(isinstance(leaf, jax.core.Tracer)
                        for leaf in jax.tree_util.tree_leaves(state))):
        return _run_masked_stepped(spec, state, batch_shape, hook)

    lead = _lead(spec, len(batch_shape))

    def cond(carry):
        s, r = carry
        return jnp.any(spec.live(s, r))

    def body(carry):
        s, r = carry
        lv = spec.live(s, r)
        s = freeze(lv, spec.cycle(s), s, lead_axes_fn=lead)
        return s, r + jnp.where(lv, spec.rounds_per_cycle, 0)

    return jax.lax.while_loop(
        cond, body, (state, jnp.zeros(batch_shape, jnp.int32)))


@functools.partial(jax.jit, static_argnames=("spec", "batch_ndim"))
def _masked_step(spec: LoopSpec, state, rounds, batch_ndim: int):
    """One masked cycle (exactly ``run_masked``'s while body) + next mask."""
    lead = _lead(spec, batch_ndim)
    lv = spec.live(state, rounds)
    s = freeze(lv, spec.cycle(state), state, lead_axes_fn=lead)
    r = rounds + jnp.where(lv, spec.rounds_per_cycle, 0)
    return s, r, spec.live(s, r)


def _masked_heur_total(spec: LoopSpec, state, live_mask) -> int | None:
    if spec.heur is None:
        return None
    h = np.asarray(_heur_vals(spec, state))
    return int(np.sum(h * np.asarray(live_mask)))


def _run_masked_stepped(spec: LoopSpec, state, batch_shape: tuple,
                        hook: "_CycleHook"):
    """Host-stepped masked driver: the telemetry path of ``run_masked``.

    Executes the identical per-cycle body through one jitted step per
    cycle, fetching the liveness mask between steps to emit
    ``CycleEvent``s.  The iteration count and every value match the fused
    while_loop (same cond-before-body structure, same freeze select).
    """
    bn = len(batch_shape)
    n_total = int(np.prod(batch_shape, dtype=np.int64)) if batch_shape else 1
    rounds = jnp.zeros(batch_shape, jnp.int32)
    lv = np.asarray(_live_mask(spec, state, rounds))
    cycle = 0
    while bool(np.any(lv)):
        heur_total = (_masked_heur_total(spec, state, lv)
                      if hook.detail else None)
        hook.fn(CycleEvent(
            driver="masked", cycle=cycle, n_live=int(np.sum(lv)),
            rounds_total=int(np.asarray(rounds).sum()),
            gathered=n_total, heur_total=heur_total))
        cycle += 1
        state, rounds, lv_next = _masked_step(spec, state, rounds, bn)
        lv = np.asarray(lv_next)
    return state, rounds


def bucket_size(n_live: int, cap: int) -> int:
    """Sub-batch size for ``n_live`` instances: next pow2, clamped to the
    lane size.  The fixed bucket ladder {1, 2, 4, ..., cap} bounds the
    number of distinct compiled cycle shapes to <= log2(cap) + 2."""
    p = 1 << max(0, n_live - 1).bit_length() if n_live > 1 else 1
    return min(p, cap)


def _tree_take(spec: LoopSpec, state, idx, batch_ndim: int = 1):
    """Gather instances ``idx`` from every leaf's batch axis."""
    lead = _lead(spec, batch_ndim)

    def take(a):
        return jnp.take(a, idx, axis=lead(a) if lead else 0)

    return jax.tree.map(take, state)


def _tree_put(spec: LoopSpec, state, idx, sub):
    """Scatter sub-batch ``sub`` back into ``state`` at instances ``idx``."""
    lead = _lead(spec, 1)

    def put(a, s):
        ax = lead(a) if lead else 0
        return a.at[(slice(None),) * ax + (idx,)].set(s)

    return jax.tree.map(put, state, sub)


@functools.partial(jax.jit, static_argnames=("spec",))
def _compact_step(spec: LoopSpec, state, rounds):
    """One cycle on an (all-live) compacted sub-batch + its next liveness."""
    new = spec.cycle(state)
    return new, spec.live(new, rounds + spec.rounds_per_cycle)


@functools.partial(jax.jit, static_argnames=("spec",))
def _live_mask(spec: LoopSpec, state, rounds):
    return spec.live(state, rounds)


@functools.partial(jax.jit, static_argnames=("spec",))
def _heur_vals(spec: LoopSpec, state):
    """Per-instance heuristic-invocation counters (detail hooks only)."""
    return spec.heur(state)


def _emit_slot(spec: LoopSpec, refill, token, lane_state, slot: int,
               rounds_val: int) -> None:
    """Hand one finished instance (a batch-1 gather of its slot) to the hook."""
    refill.emit(token, _tree_take(spec, lane_state, jnp.asarray([slot])),
                rounds_val)


def _admit_free(spec: LoopSpec, refill, lanes, lane_states, rounds,
                slot_token: list, live_idx: list, free_idx: list) -> None:
    """Offer freed slots to the refill hook until it declines or slots run out.

    Each admitted ``(token, state1)`` pair is scattered into the first free
    slot (device_put to the lane's device first, matching the initial
    placement), its rounds counter reset to 0, and its liveness evaluated
    EXACTLY as an initial instance's would be — born-dead admissions are
    emitted immediately with ``rounds == 0`` and never run a cycle, so an
    admitted instance's trajectory is indistinguishable from a solo solve.
    """
    while True:
        n_free = int(sum(f.size for f in free_idx))
        if n_free == 0:
            return
        new = refill.admit(n_free)
        if not new:
            return
        if len(new) > n_free:
            raise ValueError(
                f"refill.admit({n_free}) returned {len(new)} admissions; "
                f"it must return at most n_free")
        for token, st1 in new:
            i = next(j for j, f in enumerate(free_idx) if f.size)
            s = int(free_idx[i][0])
            free_idx[i] = free_idx[i][1:]
            lo, hi, dev = lanes[i]
            if dev is not None:
                st1 = jax.device_put(st1, dev)
            lane_states[i] = _tree_put(spec, lane_states[i],
                                       jnp.asarray([s]), st1)
            rounds[lo + s] = 0
            slot_token[lo + s] = token
            lv = _live_mask(spec, st1, jnp.zeros(1, jnp.int32))
            if bool(np.asarray(lv)[0]):
                live_idx[i] = np.sort(np.concatenate(
                    [live_idx[i],
                     np.asarray([s], dtype=live_idx[i].dtype)]))
            else:
                _emit_slot(spec, refill, token, lane_states[i], s, 0)
                free_idx[i] = np.concatenate(
                    [free_idx[i], np.asarray([s], dtype=free_idx[i].dtype)])


def _compacted_event(spec: LoopSpec, hook: "_CycleHook", cycle: int, lanes,
                     lane_states, live_idx, rounds) -> CycleEvent:
    """Build the pre-dispatch ``CycleEvent`` of one compacted host cycle."""
    gathered = sum(bucket_size(int(li.size), hi - lo)
                   for (lo, hi, _), li in zip(lanes, live_idx) if li.size)
    heur_total = None
    if hook.detail and spec.heur is not None:
        heur_total = 0
        for st, li in zip(lane_states, live_idx):
            if li.size:
                heur_total += int(np.asarray(_heur_vals(spec, st))[li].sum())
    return CycleEvent(
        driver="compacted", cycle=cycle,
        n_live=int(sum(li.size for li in live_idx)),
        rounds_total=int(rounds.sum()), gathered=gathered,
        heur_total=heur_total)


def run_compacted(spec: LoopSpec, state, n_instances: int, *, lanes=None,
                  refill=None):
    """Early-exit compaction over a 1-D batch axis of ``n_instances``.

    Between jitted cycle segments the host gathers still-live instances
    into a dense pow2-sized sub-batch (``bucket_size``), runs ``cycle`` on
    it, and scatters the results back in input order.  Pad slots of a
    bucket duplicate a live instance and are discarded on scatter — cycles
    are per-instance pure, so duplicates cannot perturb real slots.

    Args:
      spec: the solver's ``LoopSpec`` (from a cached factory).
      state: batched solver state; every leaf's batch axis has size
        ``n_instances`` at position ``lead_axes_fn(leaf, 1)``.
      n_instances: the batch size B.
      lanes: optional list of ``(lo, hi, device)`` contiguous slices (from
        ``repro.launch.mesh.compact_lanes``).  Each lane compacts
        independently on its device; instances never cross lanes.  Default:
        one lane covering the whole batch on the default device.
      refill: optional CONTINUOUS-BATCHING hook — an object with

        * ``admit(n_free) -> [(token, state1), ...]`` — called at every
          cycle boundary where slots are free (including before cycle 0 for
          instances that are born converged); returns at most ``n_free``
          new instances, each a caller-chosen token plus a batch-1 solver
          state (the kind's ``init`` of one padded problem).  Returning
          ``[]`` declines; the loop ends when nothing is live and the hook
          declines.
        * ``emit(token, state1, rounds)`` — called EXACTLY ONCE per
          instance, the moment it leaves the live set (converged or
          rounds-capped), with a batch-1 gather of its final state and its
          solo-accounting rounds counter.  Initial instances are emitted
          with their batch index as the token; admitted instances with the
          token ``admit`` returned.  Born-dead instances (initial or
          admitted) emit immediately with ``rounds == 0``.

        Admitted instances enter with a fresh rounds counter into the SAME
        gather/cycle/scatter machinery, so every emitted trajectory —
        values and counters — bit-matches that instance's solo solve
        (tests/test_refill.py).  ``refill=None`` (default) is exactly the
        closed-batch behaviour.

    Returns ``(state, rounds)`` — same contract as ``run_masked``; results
    bit-match it (tests/test_compact.py).  With ``refill`` the returned
    arrays describe the final slot OCCUPANTS (useful only for debugging) —
    per-instance results arrive through ``emit``.
    """
    if lanes is None:
        lanes = [(0, n_instances, None)]
    rounds = np.zeros(n_instances, np.int32)
    slot_token: list = list(range(n_instances))

    # Split into per-lane states (pinned to the lane's device, if any) and
    # evaluate initial liveness; fetch masks only after every lane has
    # dispatched so devices start concurrently.
    lane_states, masks, live_idx = [], [], []
    for lo, hi, dev in lanes:
        sub = _tree_take(spec, state, jnp.arange(lo, hi))
        if dev is not None:
            sub = jax.device_put(sub, dev)
        lane_states.append(sub)
        masks.append(_live_mask(spec, sub, jnp.zeros(hi - lo, jnp.int32)))
    for m in masks:
        live_idx.append(np.nonzero(np.asarray(m))[0])

    free_idx: list = []
    if refill is not None:
        # born-dead initial instances emit immediately (rounds = 0) and
        # free their slots for admission before the first cycle
        for i, (lo, hi, dev) in enumerate(lanes):
            dead = np.setdiff1d(np.arange(hi - lo, dtype=np.int64),
                                live_idx[i])
            for s in dead:
                _emit_slot(spec, refill, slot_token[lo + int(s)],
                           lane_states[i], int(s), 0)
            free_idx.append(dead)
        _admit_free(spec, refill, lanes, lane_states, rounds, slot_token,
                    live_idx, free_idx)

    hook = _cycle_hook.get()
    cycle = 0
    while any(li.size for li in live_idx):
        if hook is not None:
            hook.fn(_compacted_event(spec, hook, cycle, lanes, lane_states,
                                     live_idx, rounds))
        cycle += 1
        pending: list = [None] * len(lanes)
        for i, (lo, hi, dev) in enumerate(lanes):
            li = live_idx[i]
            if not li.size:
                continue
            m = bucket_size(int(li.size), hi - lo)
            pad = np.concatenate(
                [li, np.full(m - li.size, li[0], dtype=li.dtype)])
            sub = _tree_take(spec, lane_states[i], jnp.asarray(pad))
            new_sub, lv = _compact_step(
                spec, sub, jnp.asarray(rounds[lo:hi][pad]))
            # scatter ONLY the real slots: pad duplicates must not overwrite
            # their source instance with an extra-cycled value
            keep = _tree_take(spec, new_sub, jnp.arange(li.size))
            lane_states[i] = _tree_put(spec, lane_states[i],
                                       jnp.asarray(li), keep)
            pending[i] = lv
        for i, lv in enumerate(pending):   # host sync point, all lanes in
            if lv is None:
                continue
            li = live_idx[i]
            lo = lanes[i][0]
            rounds[lo + li] += spec.rounds_per_cycle
            keep_mask = np.asarray(lv)[:li.size]
            live_idx[i] = li[keep_mask]
            if refill is not None:
                done = li[~keep_mask]
                for s in done:
                    _emit_slot(spec, refill, slot_token[lo + int(s)],
                               lane_states[i], int(s),
                               int(rounds[lo + int(s)]))
                free_idx[i] = np.concatenate([free_idx[i], done])
        if refill is not None:
            _admit_free(spec, refill, lanes, lane_states, rounds,
                        slot_token, live_idx, free_idx)

    # Reassemble in input order (lanes are contiguous, ordered slices).
    if len(lane_states) > 1:
        home = jax.devices()[0]
        parts = [jax.device_put(s, home) if dev is not None else s
                 for (_, _, dev), s in zip(lanes, lane_states)]
        lead = _lead(spec, 1)
        state = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=lead(xs[0]) if lead else 0),
            *parts)
    else:
        state = lane_states[0]
    return state, jnp.asarray(rounds)
