"""Bipartite maximum-cardinality matching — the registry's third kind.

This package is the proof-of-seam for the solver-kind registry
(``repro.core.kinds``): a complete new solver — lock-free BFS
augmenting-path matching after Deveci et al. (arXiv:1303.1379), as one
``LoopSpec`` plus a pallas frontier kernel — rides the ragged pad-and-
bucket front end, pow2 bucketing, mesh sharding, early-exit compaction,
and the async serving engine with ZERO changes to those layers, purely by
registering itself here.  See docs/solvers.md for the add-a-kind
walkthrough this package follows.

NOTE: unlike the other solver subpackages this one has a real
``__init__`` on purpose — importing ``repro.core.matching`` is what
registers the ``"matching"`` kind, and the registry's lazy builtin import
relies on that side effect.

Payload forms accepted by the validator (both canonicalize to a dense
``(nl, nr)`` bool numpy adjacency):

  * a dense 2-D bool or 0/1 array — ``adj[i, j]`` iff left ``i`` ~ right
    ``j``;
  * an ``(edges, (nl, nr))`` tuple, ``edges`` an ``(E, 2)`` integer array
    of ``(left, right)`` endpoint ids.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.batch import (BucketStats, PreparedBucket, _make_buckets,
                              _stats)
from repro.core.kinds import SolverKind, register_kind
from repro.core.refill import RefillRuntime
from repro.core.matching.bfs import (MatchingResult, _matching_spec,
                                     match_bipartite, match_bipartite_batch)
from repro.core.matching.ref import hopcroft_karp

__all__ = [
    "MatchingResult", "match_bipartite", "match_bipartite_batch",
    "hopcroft_karp", "validate_matching_problem", "pad_matching_problem",
    "inert_matching_problem", "prepare_matching_buckets",
    "solve_prepared_matching",
]


def validate_matching_problem(payload) -> np.ndarray:
    """Canonicalize + validate a matching request (the kind's validator).

    Same reject-before-ticket contract as the other kinds: malformed
    requests raise ``ValueError`` before any queue entry or future exists.
    Accepts a dense bool / 0-1 adjacency or an ``(edges, (nl, nr))``
    tuple; returns the dense ``(nl, nr)`` bool adjacency.
    """
    if (isinstance(payload, tuple) and len(payload) == 2
            and isinstance(payload[1], (tuple, list))
            and len(payload[1]) == 2
            and np.asarray(payload[0]).ndim == 2
            and np.asarray(payload[0]).shape[-1] == 2):
        edges = np.asarray(payload[0])
        nl, nr = (int(s) for s in payload[1])
        if nl < 1 or nr < 1:
            raise ValueError(
                f"malformed matching problem: empty side in shape "
                f"({nl}, {nr})")
        if not np.issubdtype(edges.dtype, np.integer):
            raise ValueError(
                f"malformed matching problem: edge list must hold integer "
                f"vertex ids, got dtype {edges.dtype}")
        if edges.size and edges.min() < 0:
            raise ValueError(
                f"malformed matching problem: negative vertex id "
                f"{int(edges.min())} in edge list")
        if edges.size and (edges[:, 0].max() >= nl
                           or edges[:, 1].max() >= nr):
            raise ValueError(
                f"malformed matching problem: edge endpoint out of range "
                f"for shape ({nl}, {nr})")
        adj = np.zeros((nl, nr), bool)
        adj[edges[:, 0], edges[:, 1]] = True
        return adj
    try:
        a = np.asarray(payload)
    except (TypeError, ValueError) as e:
        raise ValueError(f"malformed matching problem: not array-like ({e})")
    if a.ndim != 2 or a.dtype == object:
        raise ValueError(
            f"malformed matching problem: need a 2-D (nl, nr) adjacency "
            f"or an (edges, (nl, nr)) tuple, got shape {a.shape} dtype "
            f"{a.dtype}")
    if a.shape[0] < 1 or a.shape[1] < 1:
        raise ValueError(
            f"malformed matching problem: empty side in shape {a.shape}")
    if a.dtype != bool:
        if not (np.issubdtype(a.dtype, np.integer)
                or np.issubdtype(a.dtype, np.floating)):
            raise ValueError(
                f"malformed matching problem: non-numeric adjacency dtype "
                f"{a.dtype}")
        if not np.isin(np.asarray(a), (0, 1)).all():
            raise ValueError(
                "malformed matching problem: adjacency entries must be "
                "0/1 (not a bipartite adjacency matrix)")
    return a.astype(bool)


def pad_matching_problem(adj, NL: int, NR: int) -> np.ndarray:
    """Pad an adjacency with edge-less vertices to (NL, NR) —
    value-preserving: isolated vertices join no matching."""
    adj = np.asarray(adj, bool)
    nl, nr = adj.shape
    assert NL >= nl and NR >= nr, (NL, NR, nl, nr)
    return np.pad(adj, ((0, NL - nl), (0, NR - nr)))


def inert_matching_problem(nl: int, nr: int) -> np.ndarray:
    """An edge-less instance: zero liveness seed, converges in 0 rounds —
    the matching kind's shard-padding filler."""
    return np.zeros((nl, nr), bool)


def prepare_matching_buckets(
    payloads: Iterable,
    *,
    bucket: str = "max",
    mesh=None,
    mesh_axis: str | None = None,
) -> list[PreparedBucket]:
    """HOST stage of the ``"matching"`` kind: bucket, pad, and stack.

    Payloads run through ``validate_matching_problem`` (idempotent for
    already-dense adjacencies), so both the dense and the
    ``(edges, (nl, nr))`` edge-list forms work here exactly as they do at
    engine submit time.
    """
    adjs = [validate_matching_problem(p) for p in payloads]
    shapes = [a.shape for a in adjs]

    def build(bshape, idxs, n_pad):
        NL, NR = bshape
        mats = [pad_matching_problem(adjs[i], NL, NR) for i in idxs]
        mats += [inert_matching_problem(NL, NR)] * n_pad
        return jnp.asarray(np.stack(mats)), None

    return _make_buckets("matching", shapes, bucket=bucket, mesh=mesh,
                         mesh_axis=mesh_axis, build=build)


def solve_prepared_matching(
    prep: PreparedBucket,
    *,
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
    **solver_kw,
) -> tuple[dict[int, MatchingResult], BucketStats]:
    """DEVICE stage of the ``"matching"`` kind: one batched dispatch.

    Returns ``({request_position: result}, BucketStats)``; ``match_row``
    / ``match_col`` are cropped back to the request's original (nl, nr)
    (padded vertices are isolated, so the crop discards only ``-1``s and
    the cardinality is unchanged).
    """
    res = match_bipartite_batch(prep.stacked, compact=compact, mesh=mesh,
                                mesh_axis=mesh_axis, **solver_kw)
    out: dict[int, MatchingResult] = {}
    for b, i in enumerate(prep.idxs):
        nl, nr = prep.shapes[b]
        out[i] = MatchingResult(
            match_row=res.match_row[b, :nl],
            match_col=res.match_col[b, :nr],
            cardinality=res.cardinality[b],
            rounds=res.rounds[b],
            converged=res.converged[b],
        )
    return out, _stats("matching", prep, res.rounds, res.converged, compact)


def _matching_inert(shape: tuple) -> np.ndarray:
    return inert_matching_problem(*shape)


def _matching_loop_spec(*, max_rounds: int = 10_000, backend: str = "xla"):
    """The matching solver's cached ``LoopSpec`` factory
    (``match_bipartite`` defaults); see ``repro.core.matching.bfs``."""
    return _matching_spec(max_rounds, backend)


def _matching_refill(*, max_rounds: int = 10_000, greedy_init: bool = True,
                     backend: str = "xla") -> RefillRuntime:
    """The ``"matching"`` kind's continuous-batching runtime
    (``repro.core.refill``): isolated-vertex padding in, match-vector crop
    out — the same jitted init/finalize as ``_match_batch_compact``, so a
    refilled instance bit-matches its closed-batch solve."""
    from repro.core.matching.bfs import (_match_finalize_jit, _match_init_jit,
                                         _matching_spec)
    spec = _matching_spec(max_rounds, backend)

    def pad_one(adj, shape):
        NL, NR = shape
        return jnp.asarray(pad_matching_problem(adj, NL, NR))[None]

    def init(stacked):
        return _match_init_jit(jnp.asarray(stacked, jnp.bool_),
                               greedy_init=greedy_init)

    def finalize(stacked, state, rounds) -> MatchingResult:
        return _match_finalize_jit(state, rounds)

    def crop(res: MatchingResult, shape, original) -> MatchingResult:
        nl, nr = shape
        return MatchingResult(
            match_row=res.match_row[0, :nl],
            match_col=res.match_col[0, :nr],
            cardinality=res.cardinality[0],
            rounds=res.rounds[0], converged=res.converged[0])

    def shape_of(adj) -> tuple:
        return tuple(np.asarray(adj).shape)

    return RefillRuntime(spec=spec, pad_one=pad_one, init=init,
                         finalize=finalize, crop=crop, shape_of=shape_of)


def _matching_init_state(**solver_kw):
    """Cold per-instance init — the refill runtime's init, registered on
    the warm seam so mixed warm/cold batches share one code path."""
    return _matching_refill(**solver_kw).init


def _matching_warm_state(*, max_rounds: int = 10_000,
                         greedy_init: bool = True, backend: str = "xla"):
    """Warm per-instance init: seed the state with the prior matched pairs
    that survive the mutated adjacency and let the augmenting phases
    restore maximality (``repro.core.matching.bfs._match_warm``)."""
    from repro.core.matching.bfs import _match_warm_jit

    def warm1(stacked1, solution, *, base_problem1=None, delta_bound=None):
        adj = jnp.asarray(stacked1, jnp.bool_)
        mr = jnp.asarray(solution["match_row"], jnp.int32)
        mr = jnp.pad(mr, (0, adj.shape[-2] - mr.shape[-1]),
                     constant_values=-1)[None]
        return _match_warm_jit(adj, mr, greedy_init=greedy_init)

    return warm1


def _matching_solution_of(res: MatchingResult):
    """Cacheable artifact: the matched forest's row side (the column side
    is rebuilt from it at warm time)."""
    return {"match_row": res.match_row}


register_kind(SolverKind(
    name="matching",
    validate=validate_matching_problem,
    inert_problem=_matching_inert,
    prepare_buckets=prepare_matching_buckets,
    solve_prepared=solve_prepared_matching,
    loop_spec=_matching_loop_spec,
    refill=_matching_refill,
    init_state=_matching_init_state,
    warm_state=_matching_warm_state,
    solution_of=_matching_solution_of,
))
