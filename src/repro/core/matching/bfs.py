"""Bipartite maximum-cardinality matching via lock-free BFS phases — on TPU.

The repo's THIRD solver kind, and the registry's proof-of-seam (ROADMAP:
"a new kind should be ~one ``LoopSpec`` + kernels").  Adapted from the
GPU augmenting-path matching of Deveci, Kaya, Uçar & Çatalyürek
(arXiv:1303.1379): each phase grows an alternating-BFS forest from every
unmatched row simultaneously, columns are claimed lock-free, and one
vertex-disjoint augmenting path per tree is flipped.  Their CUDA kernels
resolve column claims with atomics — thread order decides the winner; here
the claim is a deterministic keyed minimum (smallest root label, then
smallest row index), so a phase is a pure function of the instance and
results bit-match across every batching/sharding/compaction layout.

One heuristic cycle = one phase:

1. FOREST — fixpoint of frontier expansion: labeled rows reach columns
   over non-matching edges (``repro.kernels.frontier`` under
   ``backend="pallas"``; a masked keyed-min reduction under ``"xla"``);
   a newly claimed column records its claiming row as parent and, if
   matched, labels its matched row with the same root.  Claims are
   permanent within a phase — merging trees never shrinks the REACHABLE
   set, so a free column is labeled iff an augmenting path exists (Berge).
2. AUGMENT — each root selects its minimum labeled free column as the one
   endpoint of its tree; the walks back along parent pointers are vertex-
   disjoint (vertices carry exactly one root label, one endpoint per
   root), so every path flips simultaneously with collision-free scatters.
3. LIVENESS — ``progress`` records whether the phase augmented AND a free
   row with edges remains; a phase that finds no endpoint certifies
   maximality (no augmenting path exists), which is the convergence flag.

Everything is shape-polymorphic over leading batch axes and PER-INSTANCE
PURE (the fixpoint/walk ``while_loop`` predicates are shared across the
batch but extra iterations are exact no-ops), so the solver plugs into the
unified runtime of ``repro.core.solver_loop`` unchanged: masked iteration,
early-exit compaction (``compact=True``), and mesh sharding all bit-match
a loop of single-instance solves (tests/test_matching.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.solver_loop import (LoopSpec, masked_events_active,
                                    run_compacted, run_masked)

INF = jnp.int32(2 ** 30)


class MatchingResult(NamedTuple):
    match_row: jax.Array    # (..., nl) int32: matched col per row, -1 = free
    match_col: jax.Array    # (..., nr) int32: matched row per col, -1 = free
    cardinality: jax.Array  # (...,) int32 matching size
    rounds: jax.Array       # (...,) BFS phases executed per instance
    converged: jax.Array    # (...,) bool: True = maximum certified (Berge)


class MatchState(NamedTuple):
    """Per-instance solver carry (all leaves lead with the batch axes)."""

    adj: jax.Array        # (..., nl, nr) bool adjacency (constant)
    match_row: jax.Array  # (..., nl) int32
    match_col: jax.Array  # (..., nr) int32
    progress: jax.Array   # (...,) bool: an augmenting path may still exist


def _has_free_work(adj, match_row):
    """A free row with at least one edge remains (necessary for any
    augmenting path — every augmenting path starts at such a row)."""
    return jnp.any((match_row < 0) & jnp.any(adj, axis=-1), axis=-1)


def _greedy_match(adj, match_row, match_col):
    """Deterministic maximal greedy matching (the phase-0 init of Deveci
    et al.): free rows propose their minimum free column; each column
    accepts its minimum proposer; repeat to fixpoint.  Per-instance pure —
    the shared ``changed`` predicate only adds exact no-op iterations."""
    *_, nl, nr = adj.shape
    rows_i = jnp.arange(nl, dtype=jnp.int32)
    cols_i = jnp.arange(nr, dtype=jnp.int32)

    def body(carry):
        mr, mc, _ = carry
        free_r = (mr < 0)[..., :, None]
        free_c = (mc < 0)[..., None, :]
        prop = jnp.min(jnp.where(adj & free_r & free_c, cols_i, INF),
                       axis=-1)                          # (..., nl) col | INF
        # each proposed column accepts its minimum proposing row
        bids = jnp.where(prop[..., :, None] == cols_i,
                         rows_i[:, None], INF)           # (..., nl, nr)
        acc = jnp.min(bids, axis=-2)                     # (..., nr) row | INF
        won = (prop < INF) & (jnp.take_along_axis(
            acc, jnp.minimum(prop, nr - 1), axis=-1) == rows_i)
        mr = jnp.where(won, prop, mr)
        mc = jnp.where(acc < INF, acc, mc)
        return mr, mc, jnp.any(won)

    mr, mc, _ = jax.lax.while_loop(
        lambda c: c[2], body, (match_row, match_col, jnp.bool_(True)))
    return mr, mc


def _expand(adj, root_row, match_row, backend: str):
    """One frontier sweep: per column, (min root, claiming row) over labeled
    rows adjacent via non-matching edges — the kernel's contract."""
    if backend == "pallas":
        from repro.kernels.frontier.ops import frontier_op
        op = frontier_op
        for _ in range(adj.ndim - 2):  # one vmap per leading batch axis
            op = jax.vmap(op)
        return op(adj, root_row, match_row)
    *_, nl, nr = adj.shape
    cols_i = jnp.arange(nr, dtype=jnp.int32)
    rows2d = jax.lax.broadcasted_iota(jnp.int32, (nl, nr), 0)
    cand = jnp.where(
        adj & (root_row[..., :, None] < INF)
        & (match_row[..., :, None] != cols_i),
        root_row[..., :, None], INF)
    min_root = jnp.min(cand, axis=-2)
    claim = jnp.min(jnp.where(cand == min_root[..., None, :], rows2d, INF),
                    axis=-2)
    return min_root, claim


def _phase(state: MatchState, backend: str) -> MatchState:
    """One lock-free BFS augmenting-path phase (the LoopSpec cycle)."""
    adj, match_row, match_col, _ = state
    *_, nl, nr = adj.shape
    rows_i = jnp.arange(nl, dtype=jnp.int32)
    cols_i = jnp.arange(nr, dtype=jnp.int32)
    batch = match_row.shape[:-1]

    # ---- 1. alternating-BFS forest from every free row ------------------
    root_row0 = jnp.where(match_row < 0, rows_i, INF)          # (..., nl)
    root_col0 = jnp.full(batch + (nr,), INF)
    parent0 = jnp.zeros(batch + (nr,), jnp.int32)

    def bfs_body(carry):
        root_row, root_col, parent, _ = carry
        min_root, claim = _expand(adj, root_row, match_row, backend)
        newly = (root_col >= INF) & (min_root < INF)
        root_col = jnp.where(newly, min_root, root_col)
        parent = jnp.where(newly, claim, parent)
        # a labeled column's matched row inherits its root label
        rc = jnp.take_along_axis(root_col, jnp.maximum(match_row, 0),
                                 axis=-1)                      # (..., nl)
        row_new = (match_row >= 0) & (root_row >= INF) & (rc < INF)
        root_row = jnp.where(row_new, rc, root_row)
        return (root_row, root_col, parent,
                jnp.any(newly) | jnp.any(row_new))

    root_row, root_col, parent, _ = jax.lax.while_loop(
        lambda c: c[3], bfs_body,
        (root_row0, root_col0, parent0, jnp.bool_(True)))

    # ---- 2. one endpoint per tree, then flip all paths at once ----------
    free_lab = (match_col < 0) & (root_col < INF)              # (..., nr)
    owned = free_lab[..., None, :] & (root_col[..., None, :]
                                      == rows_i[..., :, None])  # (nl, nr)
    endpoint = jnp.min(jnp.where(owned, cols_i, INF), axis=-1)  # (..., nl)
    found = endpoint < INF
    cur0 = jnp.where(found, endpoint, -1)

    def walk_body(carry):
        mr, mc, cur = carry
        active = cur >= 0
        row = jnp.take_along_axis(parent, jnp.maximum(cur, 0), axis=-1)
        prev = jnp.take_along_axis(match_row, jnp.maximum(row, 0), axis=-1)
        # paths are vertex-disjoint: at most one walker writes each slot,
        # so a masked keyed min IS the scatter
        row_hit = active[..., :, None] & (rows_i == row[..., :, None])
        col_for_row = jnp.min(
            jnp.where(row_hit, cur[..., :, None], INF), axis=-2)
        mr = jnp.where(col_for_row < INF, col_for_row, mr)
        col_hit = active[..., :, None] & (cols_i == cur[..., :, None])
        row_for_col = jnp.min(
            jnp.where(col_hit, row[..., :, None], INF), axis=-2)
        mc = jnp.where(row_for_col < INF, row_for_col, mc)
        # step back over the matched edge; a free (root) row ends the walk
        return mr, mc, jnp.where(active, prev, cur)

    match_row, match_col, _ = jax.lax.while_loop(
        lambda c: jnp.any(c[2] >= 0), walk_body,
        (match_row, match_col, cur0))

    # ---- 3. liveness: augmented AND something left to try ---------------
    progress = jnp.any(found, axis=-1) & _has_free_work(adj, match_row)
    return MatchState(adj=adj, match_row=match_row, match_col=match_col,
                      progress=progress)


@functools.lru_cache(maxsize=None)
def _matching_spec(max_rounds: int, backend: str) -> LoopSpec:
    """The matching solver's registration with the solver-loop runtime.

    Cached per static-knob tuple so repeated solves hand the runtime the
    SAME spec object and the compacted drivers' jitted cycles cache-hit.
    One cycle = one BFS augmenting-path phase; the cycle is shape-
    polymorphic, so one spec serves every (nl, nr) and every compaction
    sub-batch size.
    """

    def cycle(state: MatchState) -> MatchState:
        return _phase(state, backend)

    def live(state: MatchState, rounds: jax.Array) -> jax.Array:
        return state.progress & (rounds < max_rounds)

    return LoopSpec(cycle=cycle, live=live, rounds_per_cycle=1,
                    lead_axes_fn=None)


def _match_init(adj, *, greedy_init: bool) -> MatchState:
    """Initial state: optional maximal greedy matching, then the liveness
    seed — a phase can only help while a free row with edges exists (an
    all-isolated or perfectly matched instance converges in 0 rounds)."""
    adj = jnp.asarray(adj, jnp.bool_)
    *batch, nl, nr = adj.shape
    mr = jnp.full(tuple(batch) + (nl,), -1, jnp.int32)
    mc = jnp.full(tuple(batch) + (nr,), -1, jnp.int32)
    if greedy_init:
        mr, mc = _greedy_match(adj, mr, mc)
    return MatchState(adj=adj, match_row=mr, match_col=mc,
                      progress=_has_free_work(adj, mr))


def _match_warm(adj, mr_prior, *, greedy_init: bool) -> MatchState:
    """Warm state: keep the prior matched pairs that survive the new
    adjacency, then let the unchanged augmenting phases restore maximality.

    Any valid matching is a sound starting forest for BFS augmentation
    (Berge: a matching is maximum iff no augmenting path exists — the
    phases find and apply exactly those paths), and maximum CARDINALITY is
    unique, so a warm solve lands on the same optimum as a cold one.  The
    prior pairs are scrubbed against the new adjacency (an edge deleted by
    the delta unmatches both endpoints) and re-checked for mutual
    consistency, so even a stale or foreign cache entry degrades to a
    smaller-but-valid seed rather than an invalid state.  ``greedy_init``
    additionally extends the seed with the phase-0 greedy pass (it only
    proposes free-row/free-col pairs, so the kept pairs are untouched).
    """
    adj = jnp.asarray(adj, jnp.bool_)
    *batch, nl, nr = adj.shape
    rows_i = jnp.arange(nl, dtype=jnp.int32)
    cols_i = jnp.arange(nr, dtype=jnp.int32)
    mr = jnp.asarray(mr_prior, jnp.int32)
    # a pair survives only if its edge still exists
    edge = jnp.take_along_axis(
        adj, jnp.maximum(mr, 0)[..., :, None], axis=-1)[..., 0]
    mr = jnp.where((mr >= 0) & (mr < nr) & edge, mr, -1)
    # rebuild the column side from the row side (mutual consistency even if
    # the cached pair list was inconsistent); ties keep the minimum row
    hit = mr[..., :, None] == cols_i
    mc = jnp.min(jnp.where(hit, rows_i[..., :, None], INF), axis=-2)
    mc = jnp.where(mc < INF, mc, -1)
    # and scrub rows that lost the tie so (mr, mc) is a matching
    back = jnp.take_along_axis(mc, jnp.maximum(mr, 0), axis=-1)
    mr = jnp.where((mr >= 0) & (back == rows_i), mr, -1)
    if greedy_init:
        mr, mc = _greedy_match(adj, mr, mc)
    return MatchState(adj=adj, match_row=mr, match_col=mc,
                      progress=_has_free_work(adj, mr))


_match_warm_jit = jax.jit(_match_warm, static_argnames=("greedy_init",))


def _match_finalize(state: MatchState, rounds) -> MatchingResult:
    """Result view: ``converged`` is the Berge certificate — the last phase
    found no augmenting path (False only when ``max_rounds`` was hit)."""
    return MatchingResult(
        match_row=state.match_row, match_col=state.match_col,
        cardinality=jnp.sum(state.match_row >= 0, axis=-1),
        rounds=rounds, converged=~state.progress)


def _solve_match(adj, *, max_rounds, greedy_init, backend) -> MatchingResult:
    """Shared masked solver loop, rank-polymorphic over leading batch axes."""
    state = _match_init(adj, greedy_init=greedy_init)
    spec = _matching_spec(max_rounds, backend)
    state, rounds = run_masked(spec, state, adj.shape[:-2])
    return _match_finalize(state, rounds)


_match_init_jit = jax.jit(_match_init, static_argnames=("greedy_init",))
_match_finalize_jit = jax.jit(_match_finalize)


def _match_batch_compact(adj, *, max_rounds, greedy_init, backend,
                         lanes=None) -> MatchingResult:
    """Batched solve with early-exit compaction on the (B,) axis.

    Same driver pattern as the grid/assignment solvers: ``run_compacted``
    gathers still-live instances into dense pow2-sized sub-batches between
    jitted cycle segments.  Results bit-match the masked path.
    """
    state = _match_init_jit(jnp.asarray(adj, jnp.bool_),
                            greedy_init=greedy_init)
    spec = _matching_spec(max_rounds, backend)
    state, rounds = run_compacted(spec, state, adj.shape[0], lanes=lanes)
    return _match_finalize_jit(state, rounds)


def _match_batch_stepped(adj, *, max_rounds, greedy_init,
                         backend) -> MatchingResult:
    """Eager masked solve for cycle telemetry (public (B, ...) layout).

    Same init/finalize jits as the compacted path around an eager
    ``run_masked``, which host-steps the jitted phase under the active
    ``cycle_events(masked=True)`` hook that routed here.  Bit-matches
    ``_match_batch_impl`` (tests/test_obs.py).
    """
    state = _match_init_jit(jnp.asarray(adj, jnp.bool_),
                            greedy_init=greedy_init)
    spec = _matching_spec(max_rounds, backend)
    state, rounds = run_masked(spec, state, adj.shape[:-2])
    return _match_finalize_jit(state, rounds)


@functools.partial(jax.jit,
                   static_argnames=("max_rounds", "greedy_init", "backend"))
def match_bipartite(
    adj: jax.Array,
    *,
    max_rounds: int = 10_000,
    greedy_init: bool = True,
    backend: str = "xla",
) -> MatchingResult:
    """Maximum-cardinality matching of ONE bipartite instance.

    Args:
      adj: ``(nl, nr)`` bool adjacency — ``adj[i, j]`` iff left vertex
        ``i`` is adjacent to right vertex ``j`` (rectangular fine).
      max_rounds: BFS-phase cap; each phase augments every tree that can
        augment, so at most ``min(nl, nr)`` phases are ever needed — the
        cap exists for parity with the other kinds' ``max_rounds`` knob.
      greedy_init: start from a deterministic maximal greedy matching
        (fewer phases; identical final cardinality either way).
      backend: ``"xla"`` or ``"pallas"`` (the frontier-expansion sweep as
        a TPU kernel, ``repro.kernels.frontier``) — bit-identical results.

    Returns:
      ``MatchingResult``: ``match_row (nl,)`` / ``match_col (nr,)`` with
      ``-1`` marking unmatched vertices, the matching ``cardinality``
      (equal to Hopcroft–Karp's, ``repro.core.matching.ref``), ``rounds``
      (phases run), and ``converged`` (True = maximality certified by a
      phase that found no augmenting path — Berge's theorem).
    """
    if adj.ndim != 2:
        raise ValueError(
            f"match_bipartite solves ONE instance (adj (nl, nr), got "
            f"{adj.shape}); use match_bipartite_batch for stacked problems")
    return _solve_match(adj, max_rounds=max_rounds, greedy_init=greedy_init,
                        backend=backend)


@functools.partial(jax.jit,
                   static_argnames=("max_rounds", "greedy_init", "backend"))
def _match_batch_impl(adj, *, max_rounds, greedy_init,
                      backend) -> MatchingResult:
    """Batched solve (shard_map-able body; every leaf leads with batch)."""
    return _solve_match(adj, max_rounds=max_rounds, greedy_init=greedy_init,
                        backend=backend)


def match_bipartite_batch(
    adj: jax.Array,
    *,
    max_rounds: int = 10_000,
    greedy_init: bool = True,
    backend: str = "xla",
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
) -> MatchingResult:
    """Matching on a BATCH of same-shape bipartite instances, one dispatch.

    Args:
      adj: ``(B, nl, nr)`` bool — a plain stack of single-instance
        adjacencies (the pad-and-bucket front end for ragged shapes is
        ``repro.core.batch.solve_batch("matching", ...)``).
      max_rounds / greedy_init / backend: as in ``match_bipartite``
        (applied per instance).
      compact: early-exit compaction (``repro.core.solver_loop``) — an
        instance whose maximality is certified leaves the working set
        between jitted cycle segments instead of being select-masked until
        the batch's slowest instance finishes.  With ``mesh=``, compaction
        stays within each shard's lane (``repro.launch.mesh.compact_lanes``).
      mesh / mesh_axis: optional device mesh — the batch axis is
        partitioned under ``shard_map`` with no collectives; ``B`` must
        divide the shard count (the front end pads with inert all-False
        instances instead of raising).

    Returns ``MatchingResult`` with every leaf leading with the batch axis.

    Bit-match contract: the phase cycle is per-instance pure, so batched
    == a loop of solo solves == sharded == compacted, exactly as for the
    other two kinds (tests/test_matching.py).
    """
    if adj.ndim != 3:
        raise ValueError(
            f"match_bipartite_batch expects adj (B, nl, nr), got "
            f"{adj.shape}; use match_bipartite for a single instance")
    kw = dict(max_rounds=max_rounds, greedy_init=greedy_init,
              backend=backend)
    if compact:
        lanes = None
        if mesh is not None:
            from repro.launch.mesh import compact_lanes
            lanes = compact_lanes(mesh, mesh_axis, adj.shape[0])
        return _match_batch_compact(adj, lanes=lanes, **kw)
    if mesh is None:
        if masked_events_active():
            return _match_batch_stepped(adj, **kw)
        return _match_batch_impl(adj, **kw)
    from repro.launch.mesh import dispatch_sharded
    return dispatch_sharded(_match_batch_impl, (adj,), adj.shape[0],
                            mesh, mesh_axis, **kw)
