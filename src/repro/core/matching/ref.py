"""NumPy reference oracle + instance generators for bipartite matching.

``hopcroft_karp`` is the ground truth the jax solver
(``repro.core.matching.bfs``) is tested against: a classic sequential
Hopcroft–Karp — layered BFS to find the shortest augmenting distance, then
DFS augmentation along vertex-disjoint shortest paths — on a dense boolean
adjacency matrix.  It returns a maximum-cardinality matching, so equality
of CARDINALITY (not of the matching itself, which is generally non-unique)
is the oracle contract of tests/test_matching.py.

The generators cover the acceptance grid: random Erdős–Rényi bipartite
graphs plus the adversarial families — ``perfect_matching_instance`` (a
hidden perfect matching under noise: the answer must be exactly
``min(nl, nr)``), ``star_instance`` (one hub column adjacent to every row:
the answer is 1 + whatever the off-hub rows can do = 1 for a pure star),
and ``disconnected_instance`` (block-diagonal components, including empty
blocks — isolated vertices must never wedge a phase).
"""
from __future__ import annotations

import collections

import numpy as np


def hopcroft_karp(adj: np.ndarray) -> tuple[np.ndarray, np.ndarray, int]:
    """Maximum-cardinality bipartite matching of a dense bool adjacency.

    Args:
      adj: ``(nl, nr)`` bool — ``adj[i, j]`` iff left ``i`` ~ right ``j``.

    Returns ``(match_row, match_col, cardinality)`` with ``-1`` marking an
    unmatched vertex — the same convention as ``MatchingResult``.
    """
    adj = np.asarray(adj, bool)
    nl, nr = adj.shape
    nbrs = [np.nonzero(adj[i])[0] for i in range(nl)]
    match_row = np.full(nl, -1, np.int64)
    match_col = np.full(nr, -1, np.int64)
    INF = nl + nr + 1

    def bfs() -> bool:
        """Layer free rows; True iff some free col is reachable."""
        dist = np.full(nl, INF, np.int64)
        q = collections.deque()
        for i in range(nl):
            if match_row[i] < 0:
                dist[i] = 0
                q.append(i)
        found = False
        while q:
            i = q.popleft()
            for j in nbrs[i]:
                k = match_col[j]
                if k < 0:
                    found = True
                elif dist[k] == INF:
                    dist[k] = dist[i] + 1
                    q.append(k)
        bfs.dist = dist
        return found

    def dfs(i: int) -> bool:
        for j in nbrs[i]:
            k = match_col[j]
            if k < 0 or (bfs.dist[k] == bfs.dist[i] + 1 and dfs(k)):
                match_row[i], match_col[j] = j, i
                return True
        bfs.dist[i] = INF
        return False

    while bfs():
        for i in range(nl):
            if match_row[i] < 0:
                dfs(i)
    return match_row, match_col, int(np.sum(match_row >= 0))


# ------------------------------------------------------------- generators

def random_bipartite(rng: np.random.Generator, nl: int, nr: int,
                     p: float = 0.3) -> np.ndarray:
    """Erdős–Rényi bipartite adjacency: each edge present with prob ``p``."""
    return rng.random((nl, nr)) < p


def perfect_matching_instance(rng: np.random.Generator, n: int,
                              p_noise: float = 0.2) -> np.ndarray:
    """A hidden perfect matching (a random permutation) plus noise edges.

    Maximum cardinality is exactly ``n`` — adversarial for augmenting-path
    solvers because greedy initialization on the noise edges strands rows
    that only long alternating paths can recover.
    """
    adj = rng.random((n, n)) < p_noise
    adj[np.arange(n), rng.permutation(n)] = True
    return adj


def star_instance(nl: int, nr: int, hub: int = 0) -> np.ndarray:
    """Every row adjacent to the single hub column only: max matching = 1.

    Maximal contention — every BFS tree claims the same column, so exactly
    one root may win per phase and the deterministic claim rule is load-
    bearing.
    """
    adj = np.zeros((nl, nr), bool)
    adj[:, hub] = True
    return adj


def disconnected_instance(rng: np.random.Generator,
                          blocks: list[tuple[int, int]],
                          p: float = 0.5) -> np.ndarray:
    """Block-diagonal components (a zero block = isolated vertices)."""
    nl = sum(b[0] for b in blocks)
    nr = sum(b[1] for b in blocks)
    adj = np.zeros((nl, nr), bool)
    r = c = 0
    for bl, br in blocks:
        if bl and br:
            adj[r:r + bl, c:c + br] = rng.random((bl, br)) < p
        r, c = r + bl, c + br
    return adj
