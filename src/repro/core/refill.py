"""Continuous batching: a per-kind refill session over ``run_compacted``.

A closed compacted batch still wastes slots: once an instance converges
its slot sits idle until the whole batch drains.  ``run_compacted``'s
``refill=`` hook (``repro.core.solver_loop``) lets new instances enter
vacated slots at the cycle boundary where the host re-gathers the live
set anyway — the solver analogue of the admit-each-step continuous
batching that keeps LLM serving loops saturated under ragged request
streams.

This module turns that low-level hook protocol into a per-kind SESSION:

* ``RefillRuntime`` — what a solver kind registers (the optional
  ``refill`` factory field of ``repro.core.kinds.SolverKind``): its
  ``LoopSpec`` plus the pad-one/init/finalize/crop pieces needed to bring
  a single request into, and out of, an in-flight batched state.
* ``RefillSolver`` — one continuous-batching session of one kind on one
  fixed bucket shape: seed it with initial payloads, hand it an ``admit``
  callback that supplies more as slots free up, and receive per-request
  results THE MOMENT each instance converges (``on_result``), not when
  the batch drains.

Bit-match contract (tests/test_refill.py): because cycles are
per-instance pure and every admission enters with a fresh rounds counter
through the same gather/cycle/scatter machinery as an initial instance,
a refilled session delivers, for EVERY request, exactly the result —
values and iteration counters — of that request's solo solve through the
closed-batch path (same padding shape).  The serving layer
(``repro.serve.scheduler``) builds its mid-solve admission on this class;
``RefillSolver`` itself is serving-agnostic and usable directly.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kinds import get_kind
from repro.core.solver_loop import LoopSpec, run_compacted

__all__ = ["RefillRuntime", "refill_runtime", "RefillSolver"]


class RefillRuntime(NamedTuple):
    """A kind's continuous-batching registration (see module docstring).

    Build through the kind's cached factory (``get_kind(k).refill(**kw)``)
    so repeated sessions share one ``LoopSpec`` object and the jitted
    cycle/init/finalize dispatches cache-hit across sessions.

    All callables follow the kind's PUBLIC batched layout (batch axis
    leading on every problem leaf); ``init``/``finalize`` own any internal
    re-layout (e.g. the grid solver's direction-axis moveaxis).
    """

    spec: LoopSpec          # the kind's solver-loop registration
    pad_one: Callable       # (payload, bucket_shape) -> batch-1 problem
    init: Callable          # stacked problem (B leading) -> solver state
    finalize: Callable      # (batch-1 problem, state1, rounds(1,)) -> result
    crop: Callable          # (batch-1 result, orig_shape, payload) -> result
    shape_of: Callable      # validated payload -> its shape tuple


def refill_runtime(kind: str, **solver_kw) -> RefillRuntime:
    """The registered refill runtime of ``kind`` with ``solver_kw`` knobs.

    Raises ``ValueError`` for kinds that registered no refill factory —
    callers (the async scheduler) treat that as "serve this kind through
    the closed-batch path".
    """
    k = get_kind(kind)
    if k.refill is None:
        raise ValueError(
            f"solver kind {kind!r} has no refill runtime; it serves "
            f"closed-batch only (register a SolverKind.refill factory to "
            f"enable continuous batching)")
    return k.refill(**solver_kw)


def _concat_problems(stacked1: list):
    """Concatenate batch-1 problems along the leading (public) batch axis."""
    if len(stacked1) == 1:
        return stacked1[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *stacked1)


class RefillSolver:
    """One continuous-batching session: one kind, one bucket shape.

    Every request is padded to ``shape`` (so all live instances share one
    compiled cycle ladder) and occupies one of ``capacity`` slots; slots
    not seeded initially — or vacated by converged instances — are offered
    back through ``admit``.  Results are delivered per instance, in
    convergence order, through ``on_result``; ``run`` also returns them
    keyed by request index.

    Args:
      kind: a registered solver kind with a refill runtime
        (``SolverKind.refill``; ``maxflow`` / ``assignment`` /
        ``matching`` all register one).
      shape: the session bucket shape — every admitted payload must fit
        componentwise (``fits``).
      capacity: number of slots (per-cycle batch width upper bound).
      mesh / mesh_axis: optional device mesh; slots split into per-device
        lanes (``repro.launch.mesh.compact_lanes`` — ``capacity`` must
        divide evenly across the mesh), admissions refill within lanes.
      tracer: optional ``repro.obs.Tracer`` — the session records a
        ``device-solve`` span around its run and ``bucket/pad`` spans
        around each payload intake (the serving engines thread their
        tracer through here). ``None`` records nothing.
      **solver_kw: the kind's static solver knobs (``backend=``,
        ``max_rounds=``, ...), forwarded to the refill runtime factory.
    """

    def __init__(self, kind: str, *, shape, capacity: int, mesh=None,
                 mesh_axis: str | None = None, tracer=None, **solver_kw):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.kind = get_kind(kind)
        self.rt = refill_runtime(kind, **solver_kw)
        self.shape = tuple(int(s) for s in shape)
        self.capacity = int(capacity)
        self.tracer = tracer
        self._solver_kw = dict(solver_kw)
        self._warm_fn = None
        self._lanes = None
        if mesh is not None:
            from repro.launch.mesh import compact_lanes
            self._lanes = compact_lanes(mesh, mesh_axis, self.capacity)

    def _warm_state1(self, problem1, payload, ws):
        """Warm per-instance state through the kind's warm seam."""
        from repro.core.warm import build_warm_state
        if self.kind.warm_state is None:
            raise ValueError(
                f"solver kind {self.kind.name!r} registered no warm_state "
                f"hook; warm admissions need one (docs/warmstart.md)")
        if self._warm_fn is None:
            self._warm_fn = self.kind.warm_state(**self._solver_kw)
        return build_warm_state(self.kind, self.rt, self._warm_fn, problem1,
                                payload, ws, self.shape)

    def fits(self, payload) -> bool:
        """Does a (validated) payload fit this session's bucket shape?"""
        s = self.rt.shape_of(payload)
        return len(s) == len(self.shape) and all(
            a <= b for a, b in zip(s, self.shape))

    def run(self, initial, *, admit: Callable | None = None,
            on_result: Callable | None = None,
            on_error: Callable | None = None,
            warm: dict | None = None) -> dict[int, Any]:
        """Drive one session to quiescence; returns ``{request_index: result}``.

        Request indices count every payload the session saw, in arrival
        order: ``initial`` first (0..len-1), then each payload returned by
        ``admit`` in return order — callers pairing requests with results
        track the same order on their side.

        Args:
          initial: up to ``capacity`` seed payloads (fewer is fine — the
            remaining slots start empty and are offered to ``admit``
            before the first cycle).
          admit: optional ``admit(n_free) -> payloads`` callback, called at
            every cycle boundary with free slots; must return at most
            ``n_free`` payloads (``[]``/``None`` declines — the session
            ends when nothing is live and ``admit`` declines).  Each item
            may be a bare payload or a ``(payload,
            repro.core.warm.WarmStart)`` pair — the pair form admits the
            instance warm-started from its cached prior solution.
          on_result: optional ``on_result(request_index, result)`` — called
            the moment that request's instance converges (NOT at session
            drain); results are bit-identical to the request's solo solve.
          on_error: optional ``on_error(request_index, exc)`` — a payload
            that fails validation/padding/init at admission, or whose
            finalize/crop raises, fails ALONE and the session continues.
            Without ``on_error`` such failures propagate and abort the
            session.
          warm: optional ``{seed_position: WarmStart}`` for the ``initial``
            payloads (positions index ``initial``); warm and cold seeds
            mix in one session via per-slot init.
        """
        from repro.core.warm import WarmStart, _concat_states
        rt, cap, shape = self.rt, self.capacity, self.shape
        initial = list(initial)
        warm = dict(warm or {})
        if len(initial) > cap:
            raise ValueError(
                f"{len(initial)} initial payloads > capacity {cap}")
        for pos in warm:
            if not 0 <= pos < len(initial):
                raise ValueError(
                    f"warm position {pos} out of range for "
                    f"{len(initial)} initial payloads")

        results: dict[int, Any] = {}
        req_of_token: dict[int, int] = {}
        problems: dict[int, Any] = {}       # request idx -> batch-1 problem
        metas: dict[int, tuple] = {}        # request idx -> (shape, payload)
        counters = {"n_req": 0}

        def _error(idx: int, e: Exception) -> None:
            if on_error is None:
                raise e
            on_error(idx, e)

        def _intake(payload):
            """Validate + pad one payload; returns its request idx (or None
            on failure, reported through ``on_error``)."""
            idx = counters["n_req"]
            counters["n_req"] += 1
            t0 = time.monotonic() if self.tracer is not None else 0.0
            try:
                p = self.kind.validate(payload)
                if not self.fits(p):
                    raise ValueError(
                        f"payload shape {rt.shape_of(p)} does not fit "
                        f"session bucket {shape}")
                p1 = rt.pad_one(p, shape)
            except Exception as e:
                _error(idx, e)
                return None
            if self.tracer is not None:
                self.tracer.record("bucket/pad", t0, time.monotonic(),
                                   kind=self.kind.name, n=1,
                                   bucket=list(shape))
            problems[idx] = p1
            metas[idx] = (rt.shape_of(p), p)
            return idx

        # seed slots: initial payloads first, inert fill for the rest
        warmstarts: dict[int, Any] = {}     # request idx -> WarmStart
        stacked1, slot = [], 0
        for pos, payload in enumerate(initial):
            idx = _intake(payload)
            if idx is None:
                continue
            req_of_token[slot] = idx       # initial tokens are slot indices
            if pos in warm:
                warmstarts[idx] = warm[pos]
            stacked1.append(problems[idx])
            slot += 1
        for _ in range(cap - slot):
            inert = self.kind.inert_problem(shape)
            stacked1.append(jax.tree.map(
                lambda a: jnp.asarray(a)[None], inert))
        if warmstarts:
            # mixed warm/cold seeding: per-slot init, concatenated along
            # each leaf's batch axis (cold slots keep the fused-init
            # trajectory — init is per-instance pure)
            states1 = []
            for token, p1 in enumerate(stacked1):
                idx = req_of_token.get(token)
                if idx in warmstarts:
                    states1.append(self._warm_state1(
                        p1, metas[idx][1], warmstarts[idx]))
                else:
                    states1.append(rt.init(p1))
            state = _concat_states(rt.spec, states1)
        else:
            state = rt.init(_concat_problems(stacked1))

        session = self

        class _Hook:
            def admit(self, n_free: int):
                if admit is None:
                    return []
                out = []
                # loop: if EVERY offered payload failed intake, re-offer —
                # an empty return here reads as a decline to the driver,
                # and a failed payload must not end the session while the
                # caller still has work to give
                while not out:
                    payloads = list(admit(n_free) or [])
                    if len(payloads) > n_free:
                        raise ValueError(
                            f"admit({n_free}) returned {len(payloads)} "
                            f"payloads; it must return at most n_free")
                    if not payloads:           # a genuine decline
                        break
                    for item in payloads:
                        ws = None
                        if (isinstance(item, tuple) and len(item) == 2
                                and isinstance(item[1], WarmStart)):
                            item, ws = item
                        idx = _intake(item)
                        if idx is None:
                            continue
                        try:
                            if ws is not None:
                                st1 = session._warm_state1(
                                    problems[idx], metas[idx][1], ws)
                            else:
                                st1 = rt.init(problems[idx])
                        except Exception as e:
                            _error(idx, e)
                            continue
                        token = cap + idx   # disjoint from the slot tokens
                        req_of_token[token] = idx
                        out.append((token, st1))
                return out

            def emit(self, token, st1, r: int):
                idx = req_of_token.get(token)
                if idx is None:            # an inert fill slot, no request
                    return
                try:
                    res1 = rt.finalize(problems[idx], st1,
                                       jnp.full((1,), r, jnp.int32))
                    res = rt.crop(res1, *metas[idx])
                except Exception as e:
                    _error(idx, e)
                    return
                results[idx] = res
                if on_result is not None:
                    on_result(idx, res)

        span = (contextlib.nullcontext() if self.tracer is None else
                self.tracer.span("device-solve", kind=self.kind.name,
                                 bucket=list(shape), capacity=cap,
                                 driver="refill"))
        with span:
            run_compacted(rt.spec, state, cap, lanes=session._lanes,
                          refill=_Hook())
        return results
