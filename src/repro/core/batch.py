"""Batched multi-instance solver engine: pad-and-bucket front end.

The paper's solvers are throughput devices — the CUDA implementations
amortize kernel-launch cost over thousands of nodes; this module amortizes
*dispatch* cost over many instances. ``solve_maxflow_batch`` /
``solve_assignment_batch`` take ragged collections of problems, pad each to
a bucket shape (zero-capacity padding for grids, a bonus-shifted block for
cost matrices — both value-preserving, see the helpers), stack every bucket
into one leading batch axis, and run ONE jitted dispatch per bucket
(``maxflow_grid_batch`` / the batch-polymorphic ``solve_assignment``).

Per-instance convergence inside a batch is handled by the solvers' liveness
masks: a converged instance is frozen via selects while the rest keep
iterating, so batched results bit-match a Python loop of single-instance
solves of the same padded problems (asserted in tests/test_batch.py).

Bucketing contract (``bucket=``):
  * ``"max"``  — every instance pads to the global max shape: one dispatch.
  * ``"pow2"`` — shapes round up to powers of two: a few dispatches, bounded
    padding waste (< 4x area for grids, < 2x for matrices).
  * ``"exact"``— no padding: one dispatch per distinct shape.
Results are always returned in input order, cropped back to original sizes.

Sharding (``mesh=``): pass a ``jax.sharding.Mesh``
(``repro.launch.mesh.make_solver_mesh``) and each bucket's batch axis is
partitioned across the mesh under ``shard_map``. Buckets whose size is not a
multiple of the shard count are padded with INERT instances (zero-capacity
grids / zero-weight matrices) that converge immediately and are dropped
before returning — so ragged queues of any size shard cleanly, and results
still bit-match the unsharded path (tests/test_shard.py). See
docs/batching.md for the full semantics.

Two-stage split (the serving scheduler's pipeline hook): each ``solve_*``
front end is the composition of a HOST stage and a DEVICE stage —

  * ``prepare_maxflow_buckets`` / ``prepare_assignment_buckets`` — pure
    host work (bucketing, padding, stacking) producing ``PreparedBucket``s;
  * ``solve_prepared_maxflow`` / ``solve_prepared_assignment`` — the jitted
    dispatch plus result cropping, returning per-request results AND a
    ``BucketStats`` record (batch occupancy, per-instance round spread,
    convergence counts).

``repro.serve.scheduler`` overlaps the host stage of batch *k+1* with the
device stage of batch *k* and feeds the stats into its adaptive
masked-vs-compacted dispatch policy; the blocking front ends below expose
the same stats through ``stats_out=``.
"""
from __future__ import annotations

from typing import Any, Iterable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assignment.cost_scaling import (AssignmentResult,
                                               solve_assignment)
from repro.core.maxflow.grid import (GridFlowResult, GridProblem,
                                     maxflow_grid_batch)

__all__ = [
    "pad_grid_problem", "stack_grid_problems", "pad_cost_matrix",
    "inert_grid_problem", "solve_maxflow_batch", "solve_assignment_batch",
    "PreparedBucket", "BucketStats", "prepare_maxflow_buckets",
    "solve_prepared_maxflow", "prepare_assignment_buckets",
    "solve_prepared_assignment",
]


def _pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length() if x > 1 else 1


def _bucket_shape(shape: tuple, mode: str, max_shape: tuple) -> tuple:
    if mode == "max":
        return max_shape
    if mode == "pow2":
        return tuple(_pow2(s) for s in shape)
    if mode == "exact":
        return shape
    raise ValueError(f"unknown bucket mode: {mode!r}")


def _shard_pad(n_real: int, mesh, mesh_axis) -> int:
    """Inert instances to append so the bucket batch splits evenly on mesh."""
    if mesh is None:
        return 0
    from repro.launch.mesh import shard_count
    return -n_real % shard_count(mesh, mesh_axis)


class PreparedBucket(NamedTuple):
    """One bucket's host-stage output: padded, stacked, dispatch-ready.

    ``idxs`` are positions in the original request sequence (results from
    the device stage are keyed by them); ``shapes`` are the requests'
    original shapes for cropping; ``originals`` holds the raw cost matrices
    for assignment buckets (weights are recomputed on unpadded values) and
    is ``None`` for max-flow. ``n_pad`` counts trailing inert instances
    appended for mesh-shard divisibility — the stacked batch is
    ``len(idxs) + n_pad`` instances, reals first.
    """

    kind: str                    # "maxflow" | "assignment"
    shape: tuple                 # bucket shape: (H, W) grids, (m,) matrices
    idxs: tuple[int, ...]        # request positions, in submission order
    shapes: tuple                # original per-request shapes
    stacked: Any                 # GridProblem of (B,4,H,W)... or (B,m,m)
    originals: tuple | None      # assignment: original (n,n) matrices
    n_pad: int                   # trailing inert shard-padding instances


class BucketStats(NamedTuple):
    """What one batched dispatch observed — the adaptive-dispatch signal.

    ``spread`` is the normalized per-instance round raggedness
    ``(rounds_max - rounds_min) / max(rounds_max, 1)`` over REAL instances:
    ~0 when the whole bucket converges together (masked dispatch is
    optimal), toward 1 when stragglers dominate (early-exit compaction
    pays — see benchmarks/RESULTS_compaction.md).
    """

    kind: str
    shape: tuple
    n_real: int
    n_pad: int
    compact: bool
    rounds_min: int
    rounds_max: int
    rounds_mean: float
    n_converged: int

    @property
    def spread(self) -> float:
        return (self.rounds_max - self.rounds_min) / max(self.rounds_max, 1)


def _stats(kind: str, prep: PreparedBucket, rounds, converged,
           compact: bool) -> BucketStats:
    r = np.asarray(rounds)[:len(prep.idxs)]          # real instances only
    c = np.asarray(converged)[:len(prep.idxs)]
    return BucketStats(
        kind=kind, shape=prep.shape, n_real=len(prep.idxs),
        n_pad=prep.n_pad, compact=compact,
        rounds_min=int(r.min()), rounds_max=int(r.max()),
        rounds_mean=float(r.mean()), n_converged=int(c.sum()))


# ---------------------------------------------------------------- max-flow

def pad_grid_problem(problem: GridProblem, H: int, W: int) -> GridProblem:
    """Zero-capacity pad a grid-cut instance to (H, W).

    Padded nodes carry no terminal or neighbour capacity, so they hold no
    excess and never push or relabel usefully — they are inert, and the
    max-flow value (and the cut restricted to the original window) of the
    padded instance equals the original's.
    """
    cap, cs, ct = problem
    h, w = cs.shape[-2:]
    assert H >= h and W >= w, (H, W, h, w)
    pad2 = ((0, H - h), (0, W - w))
    return GridProblem(
        cap_nbr=jnp.pad(cap, ((0, 0),) + pad2),
        cap_src=jnp.pad(cs, pad2),
        cap_sink=jnp.pad(ct, pad2),
    )


def stack_grid_problems(problems: Sequence[GridProblem]) -> GridProblem:
    """Stack same-shape instances into the (B, 4, H, W) batched layout."""
    return GridProblem(
        cap_nbr=jnp.stack([jnp.asarray(p.cap_nbr) for p in problems]),
        cap_src=jnp.stack([jnp.asarray(p.cap_src) for p in problems]),
        cap_sink=jnp.stack([jnp.asarray(p.cap_sink) for p in problems]),
    )


def inert_grid_problem(H: int, W: int) -> GridProblem:
    """An all-zero-capacity instance: no excess, converges in 0 rounds.

    Used to pad a bucket's batch to a multiple of the mesh shard count —
    inert instances never push, relabel, or affect their batch-mates (the
    solvers' masks are per instance), so appending them is value-preserving.
    """
    return GridProblem(
        cap_nbr=jnp.zeros((4, H, W), jnp.float32),
        cap_src=jnp.zeros((H, W), jnp.float32),
        cap_sink=jnp.zeros((H, W), jnp.float32),
    )


def prepare_maxflow_buckets(
    problems: Iterable[GridProblem],
    *,
    bucket: str = "max",
    mesh=None,
    mesh_axis: str | None = None,
) -> list[PreparedBucket]:
    """HOST stage: bucket, pad, and stack a ragged max-flow queue.

    Pure host/numpy + stacking work, no solver dispatch — this is the stage
    the async scheduler overlaps with the previous batch's device solve.
    Returns one ``PreparedBucket`` per distinct bucket shape, each already
    padded with inert instances to the mesh's shard count (if any).
    """
    problems = [GridProblem(*(jnp.asarray(a) for a in p)) for p in problems]
    if not problems:
        return []
    shapes = [tuple(p.cap_src.shape) for p in problems]
    max_shape = (max(s[0] for s in shapes), max(s[1] for s in shapes))

    buckets: dict[tuple, list[int]] = {}
    for i, s in enumerate(shapes):
        buckets.setdefault(_bucket_shape(s, bucket, max_shape), []).append(i)

    out = []
    for (H, W), idxs in buckets.items():
        padded = [pad_grid_problem(problems[i], H, W) for i in idxs]
        n_pad = _shard_pad(len(idxs), mesh, mesh_axis)
        padded += [inert_grid_problem(H, W)] * n_pad
        out.append(PreparedBucket(
            kind="maxflow", shape=(H, W), idxs=tuple(idxs),
            shapes=tuple(shapes[i] for i in idxs),
            stacked=stack_grid_problems(padded), originals=None,
            n_pad=n_pad))
    return out


def solve_prepared_maxflow(
    prep: PreparedBucket,
    *,
    backend: str = "xla",
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
    **solver_kw,
) -> tuple[dict[int, GridFlowResult], BucketStats]:
    """DEVICE stage: one batched dispatch of a prepared max-flow bucket.

    Returns ``({request_position: result}, BucketStats)`` — results are
    cropped back to each request's original (H, W), exactly as
    ``solve_maxflow_batch`` returns them.
    """
    res = maxflow_grid_batch(prep.stacked, backend=backend, compact=compact,
                             mesh=mesh, mesh_axis=mesh_axis, **solver_kw)
    out: dict[int, GridFlowResult] = {}
    for b, i in enumerate(prep.idxs):
        h, w = prep.shapes[b]
        st = res.state
        out[i] = GridFlowResult(
            flow=res.flow[b],
            cut=res.cut[b, :h, :w],
            state=st._replace(
                e=st.e[b, :h, :w], h=st.h[b, :h, :w],
                cap=st.cap[b, :, :h, :w],
                cap_src=st.cap_src[b, :h, :w],
                cap_sink=st.cap_sink[b, :h, :w],
                sink_flow=st.sink_flow[b], src_flow=st.src_flow[b]),
            rounds=res.rounds[b],
            converged=res.converged[b],
        )
    return out, _stats("maxflow", prep, res.rounds, res.converged, compact)


def solve_maxflow_batch(
    problems: Iterable[GridProblem],
    *,
    bucket: str = "max",
    backend: str = "xla",
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
    stats_out: list | None = None,
    **solver_kw,
) -> list[GridFlowResult]:
    """Solve many (possibly ragged) grid-cut instances in batched dispatches.

    Args:
      problems: iterable of ``GridProblem`` instances (any mix of shapes).
      bucket: ``"max"`` | ``"pow2"`` | ``"exact"`` — see the module
        docstring / docs/batching.md for the dispatch-count vs padding-waste
        trade-off.
      backend: solver round implementation (``"xla"`` | ``"multipush"`` |
        ``"pallas"``), forwarded to ``maxflow_grid_batch``.
      compact: early-exit compaction per bucket — converged instances are
        dropped from the working set between jitted cycle segments instead
        of being select-masked until the bucket's slowest instance finishes
        (``repro.core.solver_loop``; results bit-match, see
        docs/batching.md).
      mesh / mesh_axis: optional device mesh — each bucket's batch axis is
        sharded across it, with inert zero-capacity instances appended so
        every bucket splits evenly (dropped before returning). With
        ``compact=True``, compaction runs within each shard's lane.
      stats_out: optional list; one ``BucketStats`` per dispatched bucket is
        appended (occupancy + round-spread telemetry for the serving
        scheduler's adaptive dispatch).
      **solver_kw: forwarded to ``maxflow_grid_batch`` (e.g. ``max_rounds``).

    Returns one ``GridFlowResult`` per instance in input order, with ``cut``
    and state planes cropped back to the instance's original (H, W).
    """
    problems = list(problems)
    if not problems:
        return []
    results: list[GridFlowResult | None] = [None] * len(problems)
    for prep in prepare_maxflow_buckets(problems, bucket=bucket, mesh=mesh,
                                        mesh_axis=mesh_axis):
        out, stats = solve_prepared_maxflow(
            prep, backend=backend, compact=compact, mesh=mesh,
            mesh_axis=mesh_axis, **solver_kw)
        if stats_out is not None:
            stats_out.append(stats)
        for i, r in out.items():
            results[i] = r
    return results  # type: ignore[return-value]


# -------------------------------------------------------------- assignment

def pad_cost_matrix(w, m: int):
    """Pad an (n, n) integer weight matrix to (m, m), optimum-preserving.

    The real block gets a uniform bonus ``1 - min(0, w.min())`` so every
    real-real arc strictly beats the zero-weight dummy arcs: every optimal
    perfect matching of the padded matrix matches real rows to real columns
    (exchange argument — rerouting a real row from a dummy column to any
    real column gains ``w + bonus >= 1``), and the real block's restriction
    is exactly an optimal matching of the original. Padded weight =
    original weight + n * bonus. Caller must keep
    ``m * (m+1) * max|w + bonus|`` inside int32 (same contract as
    ``solve_assignment``).

    Returns ``(padded, bonus)``.
    """
    w = np.asarray(w)
    n = w.shape[-1]
    assert m >= n, (m, n)
    assert np.issubdtype(w.dtype, np.integer), "integer weights only"
    bonus = int(1 - min(0, int(w.min()))) if n else 1
    out = np.zeros((m, m), np.int32)
    out[:n, :n] = w + bonus
    return jnp.asarray(out), bonus


def prepare_assignment_buckets(
    costs: Sequence,
    *,
    bucket: str = "max",
    mesh=None,
    mesh_axis: str | None = None,
) -> list[PreparedBucket]:
    """HOST stage: bucket, bonus-pad, and stack a ragged assignment queue.

    Mirrors ``prepare_maxflow_buckets``; ``originals`` keeps the unpadded
    matrices so the device stage can recompute matching weights on the REAL
    costs (the padded solve runs on bonus-shifted values).
    """
    costs = [np.asarray(w) for w in costs]
    if not costs:
        return []
    sizes = [w.shape[-1] for w in costs]
    max_n = max(sizes)

    buckets: dict[tuple, list[int]] = {}
    for i, n in enumerate(sizes):
        buckets.setdefault(
            _bucket_shape((n,), bucket, (max_n,)), []).append(i)

    out = []
    for (m,), idxs in buckets.items():
        mats = [pad_cost_matrix(costs[i], m)[0] for i in idxs]
        # inert shard padding: zero-weight instances (any perfect matching
        # is optimal; converges in one short eps=1 refine) that other
        # instances never observe
        n_pad = _shard_pad(len(idxs), mesh, mesh_axis)
        mats += [jnp.zeros((m, m), jnp.int32)] * n_pad
        out.append(PreparedBucket(
            kind="assignment", shape=(m,), idxs=tuple(idxs),
            shapes=tuple((sizes[i],) for i in idxs),
            stacked=jnp.stack(mats),
            originals=tuple(costs[i] for i in idxs), n_pad=n_pad))
    return out


def solve_prepared_assignment(
    prep: PreparedBucket,
    *,
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
    **solver_kw,
) -> tuple[dict[int, AssignmentResult], BucketStats]:
    """DEVICE stage: one batched dispatch of a prepared assignment bucket.

    Returns ``({request_position: result}, BucketStats)``; weights are
    recomputed on the ORIGINAL (unpadded) costs, exactly as
    ``solve_assignment_batch`` returns them.
    """
    res = solve_assignment(prep.stacked, compact=compact, mesh=mesh,
                           mesh_axis=mesh_axis, **solver_kw)
    out: dict[int, AssignmentResult] = {}
    for b, i in enumerate(prep.idxs):
        (n,) = prep.shapes[b]
        col = res.col_of_row[b, :n]
        valid = col < n          # unconverged rows may hold dummy cols
        picked = jnp.take_along_axis(
            jnp.asarray(prep.originals[b], jnp.int32),
            jnp.minimum(col, n - 1)[:, None], axis=1)[:, 0]
        weight = jnp.sum(jnp.where(valid, picked, 0))
        out[i] = AssignmentResult(
            col_of_row=col, weight=weight,
            p_x=res.p_x[b, :n], p_y=res.p_y[b, :n],
            rounds=res.rounds[b], pushes=res.pushes[b],
            relabels=res.relabels[b], converged=res.converged[b],
        )
    return out, _stats("assignment", prep, res.rounds, res.converged,
                       compact)


def solve_assignment_batch(
    costs: Sequence,
    *,
    bucket: str = "max",
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
    stats_out: list | None = None,
    **solver_kw,
) -> list[AssignmentResult]:
    """Solve many (possibly ragged) assignment instances in batched dispatches.

    Args:
      costs: sequence of square integer weight matrices (ragged ``n`` fine).
      bucket: ``"max"`` | ``"pow2"`` | ``"exact"`` bucketing of the matrix
        sizes — see docs/batching.md.
      compact: early-exit compaction per bucket — instances whose ε
        schedule finished are dropped from the working set between jitted
        cycle segments (``repro.core.solver_loop``; results bit-match the
        masked path, see docs/batching.md).
      mesh / mesh_axis: optional device mesh — each bucket's batch axis is
        sharded across it, with inert zero-weight matrices appended so every
        bucket splits evenly (dropped before returning). With
        ``compact=True``, compaction runs within each shard's lane.
      stats_out: optional list; one ``BucketStats`` per dispatched bucket is
        appended (see ``solve_maxflow_batch``).
      **solver_kw: forwarded to ``solve_assignment`` (``method=``,
        ``max_rounds=``, ``backend=``, ...).

    Same-bucket instances are padded with ``pad_cost_matrix``, stacked to
    (B, m, m), and solved by the batch-polymorphic ``solve_assignment`` in
    one dispatch per bucket. Returns one ``AssignmentResult`` per instance
    in input order: ``col_of_row`` is cropped to the original n (a
    permutation of range(n) when ``converged`` — guaranteed by the
    bonus-shifted padding), ``weight`` is recomputed on the ORIGINAL
    weights, and prices keep the padded solver's values (cropped). If an
    instance did NOT converge (hit ``max_rounds``), rows may still point at
    dummy columns: their col values stay >= n so callers can detect them,
    and they contribute 0 to ``weight`` rather than a clamped arbitrary
    entry.
    """
    costs = list(costs)
    if not costs:
        return []
    results: list[AssignmentResult | None] = [None] * len(costs)
    for prep in prepare_assignment_buckets(costs, bucket=bucket, mesh=mesh,
                                           mesh_axis=mesh_axis):
        out, stats = solve_prepared_assignment(
            prep, compact=compact, mesh=mesh, mesh_axis=mesh_axis,
            **solver_kw)
        if stats_out is not None:
            stats_out.append(stats)
        for i, r in out.items():
            results[i] = r
    return results  # type: ignore[return-value]
