"""Batched multi-instance solver engine: pad-and-bucket front end.

The paper's solvers are throughput devices — the CUDA implementations
amortize kernel-launch cost over thousands of nodes; this module amortizes
*dispatch* cost over many instances. ``solve_batch(kind, payloads)`` takes
a ragged collection of problems of one registered solver kind
(``repro.core.kinds``), pads each to a bucket shape (value-preserving,
see the per-kind pad helpers), stacks every bucket into one leading batch
axis, and runs ONE jitted dispatch per bucket. The historical per-kind
entry points — ``solve_maxflow_batch`` / ``solve_assignment_batch`` — are
thin wrappers over the same generic path.

Per-instance convergence inside a batch is handled by the solvers' liveness
masks: a converged instance is frozen via selects while the rest keep
iterating, so batched results bit-match a Python loop of single-instance
solves of the same padded problems (asserted in tests/test_batch.py).

Bucketing contract (``bucket=``):
  * ``"max"``  — every instance pads to the global max shape: one dispatch.
  * ``"pow2"`` — shapes round up to powers of two: a few dispatches, bounded
    padding waste (< 4x area for grids, < 2x for matrices).
  * ``"exact"``— no padding: one dispatch per distinct shape.
Results are always returned in input order, cropped back to original sizes.

Sharding (``mesh=``): pass a ``jax.sharding.Mesh``
(``repro.launch.mesh.make_solver_mesh``) and each bucket's batch axis is
partitioned across the mesh under ``shard_map``. Buckets whose size is not a
multiple of the shard count are padded with INERT instances (each kind's
``inert_problem`` — an instance that converges immediately and cannot
perturb batch-mates) that are dropped before returning — so ragged queues
of any size shard cleanly, and results still bit-match the unsharded path
(tests/test_shard.py). See docs/batching.md for the full semantics.

Two-stage split (the serving scheduler's pipeline hook): each solve front
end is the composition of a HOST stage and a DEVICE stage —

  * ``prepare_buckets(kind, payloads)`` — pure host work (bucketing,
    padding, stacking) producing ``PreparedBucket``s;
  * ``solve_prepared(prep)`` — the jitted dispatch plus result cropping,
    returning per-request results AND a ``BucketStats`` record (batch
    occupancy, per-instance round spread, convergence counts).

``repro.serve.scheduler`` overlaps the host stage of batch *k+1* with the
device stage of batch *k* and feeds the stats into its adaptive
masked-vs-compacted dispatch policy; the blocking front ends below expose
the same stats through ``stats_out=``.

This module also REGISTERS the paper's two kinds (``"maxflow"`` and
``"assignment"``) with the solver-kind registry at the bottom of the file;
the third kind, ``"matching"``, registers itself in
``repro.core.matching`` — see docs/solvers.md for the walkthrough of
adding a kind.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assignment.cost_scaling import (AssignmentResult,
                                               solve_assignment)
from repro.core.kinds import SolverKind, get_kind, register_kind
from repro.core.maxflow.grid import (GridFlowResult, GridProblem,
                                     maxflow_grid_batch)
from repro.core.refill import RefillRuntime

__all__ = [
    "pad_grid_problem", "stack_grid_problems", "pad_cost_matrix",
    "inert_grid_problem", "inert_cost_matrix", "solve_maxflow_batch",
    "solve_assignment_batch", "PreparedBucket", "BucketStats",
    "prepare_buckets", "solve_prepared", "solve_batch",
    "prepare_maxflow_buckets", "solve_prepared_maxflow",
    "prepare_assignment_buckets", "solve_prepared_assignment",
    "validate_grid_problem", "validate_assignment_matrix",
]


def _pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length() if x > 1 else 1


def _bucket_shape(shape: tuple, mode: str, max_shape: tuple) -> tuple:
    if mode == "max":
        return max_shape
    if mode == "pow2":
        return tuple(_pow2(s) for s in shape)
    if mode == "exact":
        return shape
    raise ValueError(f"unknown bucket mode: {mode!r}")


def _shard_pad(n_real: int, mesh, mesh_axis) -> int:
    """Inert instances to append so the bucket batch splits evenly on mesh."""
    if mesh is None:
        return 0
    from repro.launch.mesh import shard_count
    return -n_real % shard_count(mesh, mesh_axis)


class PreparedBucket(NamedTuple):
    """One bucket's host-stage output: padded, stacked, dispatch-ready.

    ``kind`` names the registered solver kind (``repro.core.kinds``) whose
    ``solve_prepared`` consumes this bucket — the registry, not this
    module, is the source of truth for which kinds exist
    (``registered_kinds()``). ``idxs`` are positions in the original
    request sequence (results from the device stage are keyed by them);
    ``shapes`` are the requests' original shapes for cropping;
    ``originals`` holds raw per-request payloads when a kind's device
    stage needs unpadded values (the assignment kind recomputes weights on
    them) and is ``None`` otherwise. ``n_pad`` counts trailing inert
    instances appended for mesh-shard divisibility — the stacked batch is
    ``len(idxs) + n_pad`` instances, reals first.
    """

    kind: str                    # a registered solver kind name
    shape: tuple                 # bucket shape, e.g. (H, W) / (m,) / (nl, nr)
    idxs: tuple[int, ...]        # request positions, in submission order
    shapes: tuple                # original per-request shapes
    stacked: Any                 # batch-leading stacked problem pytree
    originals: tuple | None      # raw payloads, when the kind needs them
    n_pad: int                   # trailing inert shard-padding instances


class BucketStats(NamedTuple):
    """What one batched dispatch observed — the adaptive-dispatch signal.

    ``kind`` is the registered solver kind the bucket was dispatched
    through. ``spread`` is the normalized per-instance round raggedness
    ``(rounds_max - rounds_min) / max(rounds_max, 1)`` over REAL instances:
    ~0 when the whole bucket converges together (masked dispatch is
    optimal), toward 1 when stragglers dominate (early-exit compaction
    pays — see benchmarks/RESULTS_compaction.md).

    ``heur_min``/``heur_max``/``heur_mean`` summarize per-instance
    heuristic (global-relabel) invocations for kinds that report them
    (``"maxflow"``); ``None`` for kinds that don't. Under
    ``backend="balanced"`` the relabel cadence is stall-driven, so this is
    the knob-tuning signal: heur_mean ≈ rounds_mean / rounds_per_heuristic
    means the stall trigger degenerated to the fixed cadence.
    """

    kind: str
    shape: tuple
    n_real: int
    n_pad: int
    compact: bool
    rounds_min: int
    rounds_max: int
    rounds_mean: float
    n_converged: int
    heur_min: int | None = None
    heur_max: int | None = None
    heur_mean: float | None = None

    @property
    def spread(self) -> float:
        return (self.rounds_max - self.rounds_min) / max(self.rounds_max, 1)


def _stats(kind: str, prep: PreparedBucket, rounds, converged,
           compact: bool, heuristics=None) -> BucketStats:
    r = np.asarray(rounds)[:len(prep.idxs)]          # real instances only
    c = np.asarray(converged)[:len(prep.idxs)]
    heur: dict = {}
    if heuristics is not None:
        hh = np.asarray(heuristics)[:len(prep.idxs)]
        heur = dict(heur_min=int(hh.min()), heur_max=int(hh.max()),
                    heur_mean=float(hh.mean()))
    return BucketStats(
        kind=kind, shape=prep.shape, n_real=len(prep.idxs),
        n_pad=prep.n_pad, compact=compact,
        rounds_min=int(r.min()), rounds_max=int(r.max()),
        rounds_mean=float(r.mean()), n_converged=int(c.sum()), **heur)


def _make_buckets(kind: str, shapes: Sequence[tuple], *, bucket: str,
                  mesh, mesh_axis,
                  build: Callable) -> list[PreparedBucket]:
    """The shared host-stage loop every kind's ``prepare_buckets`` drives.

    Groups request positions by bucket shape (per-axis max under
    ``"max"``, per-axis pow2 under ``"pow2"``, identity under
    ``"exact"``), computes the inert shard padding, and calls
    ``build(bucket_shape, idxs, n_pad) -> (stacked, originals)`` for the
    kind-specific pad/stack work.
    """
    if not shapes:
        return []
    ndim = len(shapes[0])
    max_shape = tuple(max(s[d] for s in shapes) for d in range(ndim))
    groups: dict[tuple, list[int]] = {}
    for i, s in enumerate(shapes):
        groups.setdefault(_bucket_shape(s, bucket, max_shape), []).append(i)
    out = []
    for bshape, idxs in groups.items():
        n_pad = _shard_pad(len(idxs), mesh, mesh_axis)
        stacked, originals = build(bshape, idxs, n_pad)
        out.append(PreparedBucket(
            kind=kind, shape=bshape, idxs=tuple(idxs),
            shapes=tuple(shapes[i] for i in idxs), stacked=stacked,
            originals=originals, n_pad=n_pad))
    return out


# ------------------------------------------------- generic (registry) API

def prepare_buckets(kind: str, payloads: Sequence, *, bucket: str = "max",
                    mesh=None,
                    mesh_axis: str | None = None) -> list[PreparedBucket]:
    """HOST stage for any registered kind: bucket, pad, and stack a ragged
    queue of ``kind`` payloads (dispatches to the kind's registration —
    unknown kinds raise ``ValueError`` naming the registered ones)."""
    return get_kind(kind).prepare_buckets(payloads, bucket=bucket,
                                          mesh=mesh, mesh_axis=mesh_axis)


def solve_prepared(prep: PreparedBucket, *, compact: bool = False,
                   mesh=None, mesh_axis: str | None = None,
                   **solver_kw) -> tuple[dict[int, Any], BucketStats]:
    """DEVICE stage for any registered kind: one batched dispatch of a
    prepared bucket, routed through ``prep.kind``'s registration. Returns
    ``({payload_position: result}, BucketStats)``."""
    return get_kind(prep.kind).solve_prepared(
        prep, compact=compact, mesh=mesh, mesh_axis=mesh_axis, **solver_kw)


def solve_batch(
    kind: str,
    payloads: Iterable,
    *,
    bucket: str = "max",
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
    stats_out: list | None = None,
    warm: dict | None = None,
    **solver_kw,
) -> list:
    """Solve many (possibly ragged) instances of one registered kind.

    The generic front end every kind rides: ``prepare_buckets`` +
    ``solve_prepared`` composed back-to-back, one jitted dispatch per
    bucket, results in input order cropped back to original shapes.

    Args:
      kind: a registered solver kind name (``registered_kinds()``);
        unknown kinds raise ``ValueError`` naming the registered ones.
      payloads: the kind's problem instances (any mix of shapes).
      bucket: ``"max"`` | ``"pow2"`` | ``"exact"`` — see the module
        docstring / docs/batching.md for the dispatch-count vs
        padding-waste trade-off.
      compact: early-exit compaction per bucket (``repro.core.solver_loop``;
        results bit-match the masked default, see docs/batching.md).
      mesh / mesh_axis: optional device mesh — each bucket's batch axis is
        sharded across it, padded with the kind's inert instances so every
        bucket splits evenly (dropped before returning).
      stats_out: optional list; one ``BucketStats`` per dispatched bucket
        is appended (occupancy + round-spread telemetry for the serving
        scheduler's adaptive dispatch).
      warm: optional ``{payload_position: repro.core.warm.WarmStart}`` —
        those instances are warm-started from their cached prior solutions
        through the kind's ``warm_state`` hook, mixed into the same
        buckets as the cold instances (``repro.core.warm.solve_warm``
        drives the dispatch; docs/warmstart.md).
      **solver_kw: forwarded to the kind's solver (``backend=``,
        ``max_rounds=``, ...).
    """
    payloads = list(payloads)
    k = get_kind(kind)
    if not payloads:
        return []
    if warm:
        from repro.core.warm import solve_warm
        return solve_warm(kind, payloads, warm, bucket=bucket,
                          compact=compact, mesh=mesh, mesh_axis=mesh_axis,
                          stats_out=stats_out, **solver_kw)
    results: list = [None] * len(payloads)
    for prep in k.prepare_buckets(payloads, bucket=bucket, mesh=mesh,
                                  mesh_axis=mesh_axis):
        out, stats = k.solve_prepared(prep, compact=compact, mesh=mesh,
                                      mesh_axis=mesh_axis, **solver_kw)
        if stats_out is not None:
            stats_out.append(stats)
        for i, r in out.items():
            results[i] = r
    return results


# ---------------------------------------------------------------- max-flow

def validate_grid_problem(problem) -> GridProblem:
    """Canonicalize + validate a max-flow request (shapes, dtypes, values).

    The ``"maxflow"`` kind's registered validator — the submit-time
    contract shared by ``SolverEngine`` and ``AsyncSolverEngine``:
    malformed requests are rejected BEFORE a ticket or future exists, so a
    queue can never hold an entry that would wedge a batched flush. Checks
    shape ((4, H, W) / (H, W) / (H, W)), numeric dtype (bool and object
    arrays are refused), and values — capacities must be finite and
    non-negative (a negative or NaN capacity breaks the residual-graph
    invariants silently rather than loudly).
    """
    try:
        cap, cs, ct = (jnp.asarray(a) for a in problem)
    except (TypeError, ValueError) as e:
        raise ValueError(f"malformed grid problem: not array-like ({e})")
    if cap.ndim != 3 or cap.shape[0] != 4 or cs.shape != ct.shape \
            or cs.shape != cap.shape[1:]:
        raise ValueError(
            f"malformed grid problem: cap_nbr {cap.shape}, "
            f"cap_src {cs.shape}, cap_sink {ct.shape}; expected "
            f"(4, H, W) / (H, W) / (H, W)")
    for name, a in (("cap_nbr", cap), ("cap_src", cs), ("cap_sink", ct)):
        if not (jnp.issubdtype(a.dtype, jnp.floating)
                or jnp.issubdtype(a.dtype, jnp.integer)):
            raise ValueError(
                f"malformed grid problem: {name} has non-numeric dtype "
                f"{a.dtype} (need integer or floating capacities)")
        v = np.asarray(a)
        if not np.all(np.isfinite(v)):
            raise ValueError(
                f"malformed grid problem: {name} contains non-finite "
                f"capacities (NaN/inf)")
        if np.any(v < 0):
            raise ValueError(
                f"malformed grid problem: {name} contains negative "
                f"capacities (min={v.min()})")
    return GridProblem(cap, cs, ct)


def pad_grid_problem(problem: GridProblem, H: int, W: int) -> GridProblem:
    """Zero-capacity pad a grid-cut instance to (H, W).

    Padded nodes carry no terminal or neighbour capacity, so they hold no
    excess and never push or relabel usefully — they are inert, and the
    max-flow value (and the cut restricted to the original window) of the
    padded instance equals the original's.
    """
    cap, cs, ct = problem
    h, w = cs.shape[-2:]
    assert H >= h and W >= w, (H, W, h, w)
    pad2 = ((0, H - h), (0, W - w))
    return GridProblem(
        cap_nbr=jnp.pad(cap, ((0, 0),) + pad2),
        cap_src=jnp.pad(cs, pad2),
        cap_sink=jnp.pad(ct, pad2),
    )


def stack_grid_problems(problems: Sequence[GridProblem]) -> GridProblem:
    """Stack same-shape instances into the (B, 4, H, W) batched layout."""
    return GridProblem(
        cap_nbr=jnp.stack([jnp.asarray(p.cap_nbr) for p in problems]),
        cap_src=jnp.stack([jnp.asarray(p.cap_src) for p in problems]),
        cap_sink=jnp.stack([jnp.asarray(p.cap_sink) for p in problems]),
    )


def inert_grid_problem(H: int, W: int) -> GridProblem:
    """An all-zero-capacity instance: no excess, converges in 0 rounds.

    Used to pad a bucket's batch to a multiple of the mesh shard count —
    inert instances never push, relabel, or affect their batch-mates (the
    solvers' masks are per instance), so appending them is value-preserving.
    """
    return GridProblem(
        cap_nbr=jnp.zeros((4, H, W), jnp.float32),
        cap_src=jnp.zeros((H, W), jnp.float32),
        cap_sink=jnp.zeros((H, W), jnp.float32),
    )


def prepare_maxflow_buckets(
    problems: Iterable[GridProblem],
    *,
    bucket: str = "max",
    mesh=None,
    mesh_axis: str | None = None,
) -> list[PreparedBucket]:
    """HOST stage of the ``"maxflow"`` kind: bucket, pad, and stack.

    Pure host/numpy + stacking work, no solver dispatch — this is the stage
    the async scheduler overlaps with the previous batch's device solve.
    Returns one ``PreparedBucket`` per distinct bucket shape, each already
    padded with inert instances to the mesh's shard count (if any).
    """
    problems = [GridProblem(*(jnp.asarray(a) for a in p)) for p in problems]
    shapes = [tuple(p.cap_src.shape) for p in problems]

    def build(bshape, idxs, n_pad):
        H, W = bshape
        padded = [pad_grid_problem(problems[i], H, W) for i in idxs]
        padded += [inert_grid_problem(H, W)] * n_pad
        return stack_grid_problems(padded), None

    return _make_buckets("maxflow", shapes, bucket=bucket, mesh=mesh,
                         mesh_axis=mesh_axis, build=build)


def solve_prepared_maxflow(
    prep: PreparedBucket,
    *,
    backend: str = "xla",
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
    **solver_kw,
) -> tuple[dict[int, GridFlowResult], BucketStats]:
    """DEVICE stage of the ``"maxflow"`` kind: one batched dispatch.

    Returns ``({request_position: result}, BucketStats)`` — results are
    cropped back to each request's original (H, W), exactly as
    ``solve_maxflow_batch`` returns them.
    """
    res = maxflow_grid_batch(prep.stacked, backend=backend, compact=compact,
                             mesh=mesh, mesh_axis=mesh_axis, **solver_kw)
    out: dict[int, GridFlowResult] = {}
    for b, i in enumerate(prep.idxs):
        h, w = prep.shapes[b]
        st = res.state
        out[i] = GridFlowResult(
            flow=res.flow[b],
            cut=res.cut[b, :h, :w],
            state=st._replace(
                e=st.e[b, :h, :w], h=st.h[b, :h, :w],
                cap=st.cap[b, :, :h, :w],
                cap_src=st.cap_src[b, :h, :w],
                cap_sink=st.cap_sink[b, :h, :w],
                sink_flow=st.sink_flow[b], src_flow=st.src_flow[b],
                heur=None if st.heur is None else st.heur[b]),
            rounds=res.rounds[b],
            converged=res.converged[b],
            heuristics=None if res.heuristics is None else res.heuristics[b],
        )
    return out, _stats("maxflow", prep, res.rounds, res.converged, compact,
                       heuristics=res.heuristics)


def solve_maxflow_batch(
    problems: Iterable[GridProblem],
    *,
    bucket: str = "max",
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
    stats_out: list | None = None,
    **solver_kw,
) -> list[GridFlowResult]:
    """Solve many ragged grid-cut instances — thin wrapper over
    ``solve_batch("maxflow", ...)``; see it for the argument contract.
    ``**solver_kw`` forwards to ``maxflow_grid_batch`` (``backend=``,
    ``max_rounds=``, ...). Returns one ``GridFlowResult`` per instance in
    input order, cropped back to the instance's original (H, W)."""
    return solve_batch("maxflow", problems, bucket=bucket, compact=compact,
                       mesh=mesh, mesh_axis=mesh_axis, stats_out=stats_out,
                       **solver_kw)


# -------------------------------------------------------------- assignment

def validate_assignment_matrix(w) -> np.ndarray:
    """Canonicalize + validate an assignment request (square int matrix).

    The ``"assignment"`` kind's registered validator (same
    reject-before-ticket contract as ``validate_grid_problem``).
    """
    w = np.asarray(w)
    if w.ndim != 2 or w.shape[0] != w.shape[1] \
            or not np.issubdtype(w.dtype, np.integer):
        raise ValueError(
            f"malformed assignment request: need a square integer "
            f"matrix, got shape {w.shape} dtype {w.dtype}")
    return w


def pad_cost_matrix(w, m: int):
    """Pad an (n, n) integer weight matrix to (m, m), optimum-preserving.

    The real block gets a uniform bonus ``1 - min(0, w.min())`` so every
    real-real arc strictly beats the zero-weight dummy arcs: every optimal
    perfect matching of the padded matrix matches real rows to real columns
    (exchange argument — rerouting a real row from a dummy column to any
    real column gains ``w + bonus >= 1``), and the real block's restriction
    is exactly an optimal matching of the original. Padded weight =
    original weight + n * bonus. Caller must keep
    ``m * (m+1) * max|w + bonus|`` inside int32 (same contract as
    ``solve_assignment``).

    Returns ``(padded, bonus)``.
    """
    w = np.asarray(w)
    n = w.shape[-1]
    assert m >= n, (m, n)
    assert np.issubdtype(w.dtype, np.integer), "integer weights only"
    bonus = int(1 - min(0, int(w.min()))) if n else 1
    out = np.zeros((m, m), np.int32)
    out[:n, :n] = w + bonus
    return jnp.asarray(out), bonus


def inert_cost_matrix(m: int) -> jax.Array:
    """A zero-weight (m, m) instance: any perfect matching is optimal, the
    ε schedule collapses to one short ε=1 refine, and other instances never
    observe it — the assignment kind's shard-padding filler."""
    return jnp.zeros((m, m), jnp.int32)


def prepare_assignment_buckets(
    costs: Sequence,
    *,
    bucket: str = "max",
    mesh=None,
    mesh_axis: str | None = None,
) -> list[PreparedBucket]:
    """HOST stage of the ``"assignment"`` kind: bucket, bonus-pad, stack.

    Mirrors ``prepare_maxflow_buckets``; ``originals`` keeps the unpadded
    matrices so the device stage can recompute matching weights on the REAL
    costs (the padded solve runs on bonus-shifted values).
    """
    costs = [np.asarray(w) for w in costs]
    shapes = [(w.shape[-1],) for w in costs]

    def build(bshape, idxs, n_pad):
        (m,) = bshape
        mats = [pad_cost_matrix(costs[i], m)[0] for i in idxs]
        mats += [inert_cost_matrix(m)] * n_pad
        return jnp.stack(mats), tuple(costs[i] for i in idxs)

    return _make_buckets("assignment", shapes, bucket=bucket, mesh=mesh,
                         mesh_axis=mesh_axis, build=build)


def solve_prepared_assignment(
    prep: PreparedBucket,
    *,
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
    **solver_kw,
) -> tuple[dict[int, AssignmentResult], BucketStats]:
    """DEVICE stage of the ``"assignment"`` kind: one batched dispatch.

    Returns ``({request_position: result}, BucketStats)``; weights are
    recomputed on the ORIGINAL (unpadded) costs, exactly as
    ``solve_assignment_batch`` returns them.
    """
    res = solve_assignment(prep.stacked, compact=compact, mesh=mesh,
                           mesh_axis=mesh_axis, **solver_kw)
    out: dict[int, AssignmentResult] = {}
    for b, i in enumerate(prep.idxs):
        (n,) = prep.shapes[b]
        col = res.col_of_row[b, :n]
        valid = col < n          # unconverged rows may hold dummy cols
        picked = jnp.take_along_axis(
            jnp.asarray(prep.originals[b], jnp.int32),
            jnp.minimum(col, n - 1)[:, None], axis=1)[:, 0]
        weight = jnp.sum(jnp.where(valid, picked, 0))
        out[i] = AssignmentResult(
            col_of_row=col, weight=weight,
            p_x=res.p_x[b, :n], p_y=res.p_y[b, :n],
            rounds=res.rounds[b], pushes=res.pushes[b],
            relabels=res.relabels[b], converged=res.converged[b],
        )
    return out, _stats("assignment", prep, res.rounds, res.converged,
                       compact)


def solve_assignment_batch(
    costs: Sequence,
    *,
    bucket: str = "max",
    compact: bool = False,
    mesh=None,
    mesh_axis: str | None = None,
    stats_out: list | None = None,
    **solver_kw,
) -> list[AssignmentResult]:
    """Solve many ragged assignment instances — thin wrapper over
    ``solve_batch("assignment", ...)``; see it for the argument contract.
    ``**solver_kw`` forwards to ``solve_assignment`` (``method=``,
    ``max_rounds=``, ``backend=``, ...).

    Same-bucket instances are padded with ``pad_cost_matrix``, stacked to
    (B, m, m), and solved by the batch-polymorphic ``solve_assignment`` in
    one dispatch per bucket. Returns one ``AssignmentResult`` per instance
    in input order: ``col_of_row`` is cropped to the original n (a
    permutation of range(n) when ``converged`` — guaranteed by the
    bonus-shifted padding), ``weight`` is recomputed on the ORIGINAL
    weights, and prices keep the padded solver's values (cropped). If an
    instance did NOT converge (hit ``max_rounds``), rows may still point at
    dummy columns: their col values stay >= n so callers can detect them,
    and they contribute 0 to ``weight`` rather than a clamped arbitrary
    entry.
    """
    return solve_batch("assignment", costs, bucket=bucket, compact=compact,
                       mesh=mesh, mesh_axis=mesh_axis, stats_out=stats_out,
                       **solver_kw)


# --------------------------------------------- registry: the builtin kinds

def _maxflow_inert(shape: tuple) -> GridProblem:
    return inert_grid_problem(*shape)


def _maxflow_loop_spec(*, rounds_per_heuristic: int = 32,
                       max_rounds: int = 100_000, bfs_max_iters: int = 0,
                       backend: str = "xla", stall_threshold: float = 0.05):
    """The grid solver's cached ``LoopSpec`` factory (``maxflow_grid``
    defaults); see ``repro.core.maxflow.grid``."""
    from repro.core.maxflow.grid import _grid_spec
    return _grid_spec(rounds_per_heuristic, max_rounds, bfs_max_iters,
                      backend, stall_threshold)


def _maxflow_refill(*, rounds_per_heuristic: int = 32,
                    max_rounds: int = 100_000, bfs_max_iters: int = 0,
                    backend: str = "xla",
                    stall_threshold: float = 0.05) -> RefillRuntime:
    """The ``"maxflow"`` kind's continuous-batching runtime
    (``repro.core.refill``): the same cached spec / jitted init+finalize
    the compacted batch driver uses, so a refilled instance's trajectory
    bit-matches its closed-batch solve.  Problems use the public
    (B, 4, H, W) layout; init/finalize own the internal direction-axis
    moveaxis exactly as ``_grid_batch_compact`` does."""
    from repro.core.maxflow.grid import (_grid_finalize_jit, _grid_init_jit,
                                         _grid_spec)
    spec = _grid_spec(rounds_per_heuristic, max_rounds, bfs_max_iters,
                      backend, stall_threshold)

    def pad_one(problem: GridProblem, shape) -> GridProblem:
        H, W = shape
        return stack_grid_problems([pad_grid_problem(problem, H, W)])

    def init(stacked: GridProblem):
        return _grid_init_jit(
            jnp.moveaxis(jnp.asarray(stacked.cap_nbr), 1, 0),
            jnp.asarray(stacked.cap_src), jnp.asarray(stacked.cap_sink),
            bfs_max_iters=bfs_max_iters)

    def finalize(stacked, state, rounds) -> GridFlowResult:
        res = _grid_finalize_jit(state, rounds,
                                 bfs_max_iters=bfs_max_iters)
        return res._replace(state=res.state._replace(
            cap=jnp.moveaxis(res.state.cap, 0, 1)))

    def crop(res: GridFlowResult, shape, original) -> GridFlowResult:
        h, w = shape
        st = res.state
        return GridFlowResult(
            flow=res.flow[0], cut=res.cut[0, :h, :w],
            state=st._replace(
                e=st.e[0, :h, :w], h=st.h[0, :h, :w],
                cap=st.cap[0, :, :h, :w], cap_src=st.cap_src[0, :h, :w],
                cap_sink=st.cap_sink[0, :h, :w],
                sink_flow=st.sink_flow[0], src_flow=st.src_flow[0],
                heur=None if st.heur is None else st.heur[0]),
            rounds=res.rounds[0], converged=res.converged[0],
            heuristics=None if res.heuristics is None else res.heuristics[0])

    def shape_of(problem: GridProblem) -> tuple:
        return tuple(np.asarray(jnp.asarray(problem.cap_src)).shape)

    return RefillRuntime(spec=spec, pad_one=pad_one, init=init,
                         finalize=finalize, crop=crop, shape_of=shape_of)


def _assignment_inert(shape: tuple) -> jax.Array:
    return inert_cost_matrix(*shape)


def _assignment_refill(*, method: str = "auction", alpha: int = 10,
                       max_rounds: int = 200_000,
                       rounds_per_heuristic: int = 16,
                       use_price_update: bool = True,
                       use_arc_fixing: bool = True,
                       backend: str = "xla") -> RefillRuntime:
    """The ``"assignment"`` kind's continuous-batching runtime: bonus-
    shifted padding on the way in (``pad_cost_matrix``), weight recomputed
    on the ORIGINAL costs on the way out — exactly the
    ``solve_prepared_assignment`` crop, per instance."""
    from repro.core.assignment.cost_scaling import (_assignment_finalize_jit,
                                                    _assignment_spec,
                                                    _scale_init_jit)
    spec = _assignment_spec(method, alpha, max_rounds, rounds_per_heuristic,
                            use_price_update, use_arc_fixing, backend)

    def pad_one(w, shape):
        (m,) = shape
        return pad_cost_matrix(w, m)[0][None]

    def init(stacked):
        return _scale_init_jit(jnp.asarray(stacked, jnp.int32), alpha=alpha)

    def finalize(stacked, state, rounds) -> AssignmentResult:
        # the solver's own per-instance round/push counters live in the
        # state; the driver-side rounds argument is unused (as in the
        # closed-batch path)
        return _assignment_finalize_jit(jnp.asarray(stacked, jnp.int32),
                                        state.st)

    def crop(res: AssignmentResult, shape, original) -> AssignmentResult:
        (n,) = shape
        col = res.col_of_row[0, :n]
        valid = col < n          # unconverged rows may hold dummy cols
        picked = jnp.take_along_axis(
            jnp.asarray(original, jnp.int32),
            jnp.minimum(col, n - 1)[:, None], axis=1)[:, 0]
        weight = jnp.sum(jnp.where(valid, picked, 0))
        return AssignmentResult(
            col_of_row=col, weight=weight,
            p_x=res.p_x[0, :n], p_y=res.p_y[0, :n],
            rounds=res.rounds[0], pushes=res.pushes[0],
            relabels=res.relabels[0], converged=res.converged[0])

    def shape_of(w) -> tuple:
        return (int(np.asarray(w).shape[-1]),)

    return RefillRuntime(spec=spec, pad_one=pad_one, init=init,
                         finalize=finalize, crop=crop, shape_of=shape_of)


def _assignment_loop_spec(*, method: str = "auction", alpha: int = 10,
                          max_rounds: int = 200_000,
                          rounds_per_heuristic: int = 16,
                          use_price_update: bool = True,
                          use_arc_fixing: bool = True,
                          backend: str = "xla"):
    """The assignment solver's cached ``LoopSpec`` factory
    (``solve_assignment`` defaults); see ``repro.core.assignment``."""
    from repro.core.assignment.cost_scaling import _assignment_spec
    return _assignment_spec(method, alpha, max_rounds, rounds_per_heuristic,
                            use_price_update, use_arc_fixing, backend)


# ------------------------------------------------------ warm-start hooks
# (repro.core.warm drives these; see docs/warmstart.md)


def _pad_trailing(a, shape, fill=0):
    """Zero-pad the trailing ``len(shape)`` axes of ``a`` up to ``shape``."""
    a = jnp.asarray(a)
    tail = a.shape[a.ndim - len(shape):]
    pads = [(0, 0)] * (a.ndim - len(shape)) + [
        (0, t - s) for s, t in zip(tail, shape)]
    return jnp.pad(a, pads, constant_values=fill)


def _maxflow_init_state(**solver_kw):
    """Cold per-instance init for the ``"maxflow"`` kind — the refill
    runtime's init, registered so warm/cold mixing shares one code path."""
    return _maxflow_refill(**solver_kw).init


def _maxflow_warm_state(*, rounds_per_heuristic: int = 32,
                        max_rounds: int = 100_000, bfs_max_iters: int = 0,
                        backend: str = "xla", stall_threshold: float = 0.05):
    """Warm per-instance init: recover the prior flow from the cached
    residuals, clamp/repair it against the mutated capacities, and re-BFS
    the heights (``repro.core.maxflow.grid._grid_warm``).  Without a base
    problem the prior flow is unrecoverable from residuals alone, so the
    hook degrades to the cold init."""
    from repro.core.maxflow.grid import _grid_init_jit, _grid_warm_jit

    def warm1(problem1: GridProblem, solution, *, base_problem1=None,
              delta_bound=None):
        cap = jnp.moveaxis(jnp.asarray(problem1.cap_nbr), 1, 0)
        cs = jnp.asarray(problem1.cap_src)
        ct = jnp.asarray(problem1.cap_sink)
        if base_problem1 is None:
            return _grid_init_jit(cap, cs, ct, bfs_max_iters=bfs_max_iters)
        H, W = cs.shape[-2:]
        bcap = jnp.moveaxis(jnp.asarray(base_problem1.cap_nbr), 1, 0)
        bct = jnp.asarray(base_problem1.cap_sink)
        # cached solution arrays are at the ORIGINAL (h, w); inert padding
        # carries no flow, so zero-extending them to the bucket is exact
        pcap = _pad_trailing(solution["cap"], (H, W))[:, None]
        pct = _pad_trailing(solution["cap_sink"], (H, W))[None]
        return _grid_warm_jit(cap, cs, ct, bcap, bct, pcap, pct,
                              bfs_max_iters=bfs_max_iters)

    return warm1


def _maxflow_solution_of(res: GridFlowResult):
    """Cacheable artifact: the residual capacities (grid + sink edges) —
    with the base problem they reconstruct the full prior flow."""
    return {"cap": res.state.cap, "cap_sink": res.state.cap_sink}


def _assignment_init_state(**solver_kw):
    return _assignment_refill(**solver_kw).init


def _assignment_warm_state(*, method: str = "auction", alpha: int = 10,
                           max_rounds: int = 200_000,
                           rounds_per_heuristic: int = 16,
                           use_price_update: bool = True,
                           use_arc_fixing: bool = True,
                           backend: str = "xla"):
    """Warm per-instance init: re-enter the ε ladder at a delta-bounded
    rung with the prior column prices (``_scale_warm``; unconditionally
    correct for ANY prices — see its docstring).  ``delta_bound`` (max
    |Δw| on the original weights) turns into a scaled-cost bound of
    ``(m+1)·2·Δw`` — the factor 2 covers the bonus shift drifting with
    ``min(w)``; with no bound the ladder re-enters at the cold rung and
    only the prices carry over."""
    from repro.core.assignment.cost_scaling import _scale_warm_jit

    def warm1(stacked1, solution, *, base_problem1=None, delta_bound=None):
        w = jnp.asarray(stacked1, jnp.int32)
        m = int(w.shape[-1])
        p_y = jnp.asarray(solution["p_y"], jnp.int32)
        p_y = jnp.pad(p_y, (0, m - p_y.shape[-1]))[None]
        if delta_bound is None:
            dmax = jnp.full((1,), 2 ** 30, jnp.int32)    # clamps to cold ε
        else:
            dmax = jnp.full(
                (1,), min(2 ** 30, (m + 1) * 2 * int(np.ceil(delta_bound))),
                jnp.int32)
        return _scale_warm_jit(w, p_y, dmax, alpha=alpha)

    return warm1


def _assignment_solution_of(res: AssignmentResult):
    """Cacheable artifact: the column prices (the dual half the warm
    ladder reuses)."""
    return {"p_y": res.p_y}


register_kind(SolverKind(
    name="maxflow",
    validate=validate_grid_problem,
    inert_problem=_maxflow_inert,
    prepare_buckets=prepare_maxflow_buckets,
    solve_prepared=solve_prepared_maxflow,
    loop_spec=_maxflow_loop_spec,
    refill=_maxflow_refill,
    init_state=_maxflow_init_state,
    warm_state=_maxflow_warm_state,
    solution_of=_maxflow_solution_of,
))

register_kind(SolverKind(
    name="assignment",
    validate=validate_assignment_matrix,
    inert_problem=_assignment_inert,
    prepare_buckets=prepare_assignment_buckets,
    solve_prepared=solve_prepared_assignment,
    loop_spec=_assignment_loop_spec,
    refill=_assignment_refill,
    init_state=_assignment_init_state,
    warm_state=_assignment_warm_state,
    solution_of=_assignment_solution_of,
))
