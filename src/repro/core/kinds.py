"""Solver-kind registry: the one seam every layer above the kernels shares.

The stack above the solvers — the ragged pad-and-bucket front end
(``repro.core.batch``), the serving engine (``repro.serve.engine``), the
async scheduler (``repro.serve.scheduler``), and the benchmark runner
(``benchmarks.run``) — used to hardcode the paper's two solvers as
``"maxflow" | "assignment"`` string branches.  This module replaces every
one of those if/elif ladders with a REGISTRY: a solver kind registers once,
under a string name, the five capabilities the upper layers need, and
every layer dispatches through ``get_kind``.  Adding a new kind (the
ROADMAP's refactor-test) is then ~one ``LoopSpec`` + kernels + one
``register_kind`` call — ``repro.core.matching`` (GPU bipartite
maximum-cardinality matching, Deveci et al., arXiv:1303.1379) is the third
kind and the proof of the seam; see docs/solvers.md for the walkthrough.

A ``SolverKind`` bundles:

* ``validate(payload) -> payload`` — canonicalize + reject a malformed
  request (raises ``ValueError``) BEFORE any ticket or future exists; the
  submit-time contract of both serving engines.
* ``inert_problem(shape) -> payload`` — an instance that converges
  immediately and cannot perturb batch-mates; the pad-and-bucket front end
  appends these so every bucket splits evenly across a device mesh.
* ``prepare_buckets(payloads, *, bucket=, mesh=, mesh_axis=)`` — the HOST
  stage: pad, bucket, and stack a ragged queue into ``PreparedBucket``s.
* ``solve_prepared(prep, *, compact=, mesh=, mesh_axis=, **kw)`` — the
  DEVICE stage: one batched dispatch of a prepared bucket, returning
  ``({payload_position: result}, BucketStats)``.
* ``loop_spec(**static_kw) -> LoopSpec`` — the kind's cached ``LoopSpec``
  factory (``repro.core.solver_loop``); exposed so callers can drive the
  loop runtime directly (and so the registry documents where the kind's
  cycle actually lives).
* ``refill(**static_kw) -> RefillRuntime`` — OPTIONAL (default ``None``):
  the kind's continuous-batching runtime (``repro.core.refill``) — the
  pad-one/init/finalize/crop pieces that let the serving layer admit new
  instances of this kind into an in-flight compacted solve at cycle
  boundaries.  Kinds without one still serve through the closed-batch
  path everywhere.

Three further OPTIONAL hooks form the warm-start seam (``repro.core.warm``
drives them; all three builtin kinds register all three):

* ``init_state(**static_kw) -> (problem1) -> state1`` — the kind's COLD
  init, extracted from inside the solver and registered: builds the loop
  state for one padded batch-1 stacked problem.  The same init the solver
  uses internally, so per-instance cold init inside a mixed warm/cold
  batch bit-matches the closed-batch path.
* ``warm_state(**static_kw) -> (problem1, solution, base_problem1=,
  delta_bound=) -> state1`` — rebuild a VALID loop state for the (possibly
  delta-mutated) ``problem1`` from a previously cached ``solution``:
  clamp the prior preflow to the new capacities and repair deficits while
  keeping heights valid lower bounds (maxflow); re-enter the ε-ladder at
  a delta-bounded rung with the prior prices (assignment); keep the still-
  valid matched pairs and re-run augmenting rounds (matching).  The warm
  state must drive the UNCHANGED loop to the same optimum a cold solve of
  the mutated problem reaches.
* ``solution_of(result) -> solution`` — extract the cacheable artifact
  (the thing ``warm_state`` consumes) from one cropped per-instance
  result; ``repro.core.warm.SolutionCache`` stores and spills these.

This module imports neither jax nor the solver packages at import time —
the registry stays importable from anywhere (``repro.serve.metrics``
included) without touching device state.  The built-in kinds register
themselves when their home modules import; ``get_kind`` /
``registered_kinds`` lazily import those modules so lookups work no matter
which module the caller imported first.
"""
from __future__ import annotations

import importlib
from typing import Any, Callable, NamedTuple

__all__ = ["SolverKind", "register_kind", "get_kind", "registered_kinds"]


class SolverKind(NamedTuple):
    """One solver kind's registration — see the module docstring."""

    name: str
    validate: Callable[[Any], Any]
    inert_problem: Callable[..., Any]
    prepare_buckets: Callable[..., list]
    solve_prepared: Callable[..., tuple]
    loop_spec: Callable[..., Any]
    # optional: the kind's continuous-batching runtime factory
    # (repro.core.refill.RefillRuntime); None = closed-batch only
    refill: Callable[..., Any] | None = None
    # optional warm-start seam (repro.core.warm); None = cold-only kind.
    # init_state / warm_state are factories over the kind's static solver
    # knobs returning per-instance (batch-1) state builders; solution_of
    # maps one cropped result to its cacheable artifact.
    init_state: Callable[..., Any] | None = None
    warm_state: Callable[..., Any] | None = None
    solution_of: Callable[[Any], Any] | None = None


_REGISTRY: dict[str, SolverKind] = {}

# Modules that register the built-in kinds as an import side effect.  Lazy
# (imported on first lookup, not at this module's import) so the registry
# itself never drags jax in, and so circular imports cannot form: these
# modules import ``repro.core.kinds`` at their top, we import them only
# from inside a function call.
_BUILTIN_MODULES = ("repro.core.batch", "repro.core.matching")


def _ensure_builtins() -> None:
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)


def register_kind(kind: SolverKind) -> SolverKind:
    """Register ``kind`` under ``kind.name``; returns it for convenience.

    Duplicate names are an error (a silent overwrite would let two modules
    fight over a name and make dispatch order-of-import dependent).  There
    is deliberately no unregister: kinds are process-lifetime registrations,
    like jax's pytree registrations.
    """
    if not kind.name or not isinstance(kind.name, str):
        raise ValueError(f"kind name must be a non-empty string, "
                         f"got {kind.name!r}")
    if kind.name in _REGISTRY:
        raise ValueError(
            f"solver kind {kind.name!r} is already registered; kind names "
            f"must be unique (registered: {sorted(_REGISTRY)})")
    _REGISTRY[kind.name] = kind
    return kind


def get_kind(name: str) -> SolverKind:
    """Look up a registered kind; unknown names raise naming the known ones."""
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown solver kind {name!r}; registered kinds: "
            f"{', '.join(registered_kinds())}") from None


def registered_kinds(*, ensure: bool = True) -> tuple[str, ...]:
    """Names of every registered kind, in registration order.

    Built-in kinds (``maxflow``, ``assignment``, ``matching``) are ensured
    first, so the result is stable regardless of which module the caller
    imported.  Pass ``ensure=False`` to only PEEK at what has registered so
    far without importing the (jax-heavy) builtin solver modules — the
    jax-free metrics layer uses this.
    """
    if ensure:
        _ensure_builtins()
    return tuple(_REGISTRY)
