"""Per-instance liveness freeze — the one primitive behind batched solving.

Both batched solvers (``maxflow.grid`` and ``assignment.cost_scaling``)
replace scalar while-loop predicates with per-instance masks: each outer
iteration computes a candidate next state for the whole batch, then
``freeze`` selects the old state back in for instances whose mask is False.
Keeping the broadcast logic in one place keeps the two solvers' freeze
semantics identical — the bit-match contract of ``repro.core.batch`` rests
on it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def freeze(live, new, old, lead_axes_fn=None):
    """Select ``new`` where ``live`` else ``old``, per pytree leaf.

    ``live`` has the batch shape (``()`` for a single instance, ``(B,)`` for
    a batch); leaves carry the batch axes plus trailing data axes.
    ``lead_axes_fn(leaf) -> int`` names how many leaf axes PRECEDE the batch
    axes (e.g. the direction axis of the grid solver's ``cap``); default 0.
    """
    live = jnp.asarray(live)

    def sel(a, b):
        lead = lead_axes_fn(a) if lead_axes_fn else 0
        m = live.reshape((1,) * lead + live.shape
                         + (1,) * (a.ndim - live.ndim - lead))
        return jnp.where(m, a, b)

    return jax.tree.map(sel, new, old)
