"""Fault tolerance: preemption-triggered checkpoints, step watchdog,
elastic restart policy.

At 1000+ nodes the failure model is: (a) planned preemption (SIGTERM with a
grace window), (b) hard node loss (the run dies; the scheduler restarts it,
possibly with a different node count), (c) stragglers (a slow host stalls
every collective). The corresponding mechanisms here:

(a) ``PreemptionGuard`` installs SIGTERM/SIGINT handlers that set a flag the
    training loop polls each step; the loop then checkpoints and exits 0 so
    the scheduler treats it as a clean preemption.
(b) restart-from-latest: ``repro.checkpoint.store.latest_step`` + restore
    with the *current* mesh's shardings (resharding is automatic), and the
    stateless data pipeline resumes exactly from the step counter. A changed
    device count only changes the batch partitioning, not the data.
(c) ``StepWatchdog`` records per-step wall times and flags steps slower than
    ``threshold_x`` times the trailing median — on TPU pods the main
    actionable mitigations are (i) deterministic compile (all shapes static;
    everything here is), (ii) swapping the flagged host out at the next
    restart boundary. The watchdog emits the host-rank so the launcher can
    cordon it.
"""
from __future__ import annotations

import signal
import statistics
import time
from dataclasses import dataclass, field


class PreemptionGuard:
    def __init__(self):
        self.requested = False
        self._prev = {}

    def __enter__(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._prev[sig] = signal.signal(sig, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def __exit__(self, *exc):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        return False


@dataclass
class StepWatchdog:
    threshold_x: float = 2.0
    window: int = 50
    times: list = field(default_factory=list)
    slow_steps: list = field(default_factory=list)
    _t0: float = 0.0

    def start(self):
        self._t0 = time.monotonic()

    def stop(self, step: int) -> bool:
        """Returns True if this step was a straggler outlier."""
        dt = time.monotonic() - self._t0
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) >= 10:
            med = statistics.median(self.times)
            if dt > self.threshold_x * med:
                self.slow_steps.append((step, dt, med))
                return True
        return False

    @property
    def median(self):
        return statistics.median(self.times) if self.times else 0.0
