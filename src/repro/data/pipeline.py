"""Deterministic synthetic data pipeline (stateless => trivially resumable).

Every *row* of every batch is a pure function of (seed, step, row_index), so:
  * checkpoint/restart needs no data-iterator state (resume = set step),
  * elastic re-sharding (different host/device count after a failure)
    reproduces byte-identical data — each process materializes exactly the
    rows of its addressable shards, whatever the new partitioning is.

The token stream is a mixture of Zipf-distributed unigrams and copied spans,
so losses actually go down during the example runs (structure to learn),
unlike uniform noise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    copy_prob: float = 0.3
    frontend_dim: int = 0     # audio stub: emit frame embeddings instead


def _row_rng(cfg: DataConfig, step: int, row: int):
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, row]))


def _token_row(cfg: DataConfig, step: int, row: int):
    rng = _row_rng(cfg, step, row)
    toks = rng.zipf(cfg.zipf_a, size=cfg.seq_len + 1)
    toks = np.minimum(toks - 1, cfg.vocab - 1).astype(np.int32)
    if rng.random() < cfg.copy_prob:
        L = max(1, cfg.seq_len // 4)
        hi1 = max(1, cfg.seq_len // 2 - L)
        src = rng.integers(0, hi1)
        dst = rng.integers(cfg.seq_len // 2, max(cfg.seq_len // 2 + 1,
                                                 cfg.seq_len - L))
        span = min(L, cfg.seq_len + 1 - dst)
        toks[dst:dst + span] = toks[src:src + span]
    return toks


def _embed_row(cfg: DataConfig, step: int, row: int):
    rng = _row_rng(cfg, step, row)
    emb = rng.normal(size=(cfg.seq_len, cfg.frontend_dim)).astype(np.float32)
    labels = rng.integers(0, cfg.vocab, size=cfg.seq_len).astype(np.int32)
    return emb, labels


def rows_batch(cfg: DataConfig, step: int, start: int, stop: int):
    """Rows [start, stop) of global batch `step` — numpy dict."""
    if cfg.frontend_dim:
        pairs = [_embed_row(cfg, step, r) for r in range(start, stop)]
        return {"embeds": np.stack([p[0] for p in pairs]),
                "labels": np.stack([p[1] for p in pairs])}
    toks = np.stack([_token_row(cfg, step, r) for r in range(start, stop)])
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def host_batch(cfg: DataConfig, step: int, shard: int, n_shards: int):
    """This host's contiguous slice of global batch `step`."""
    assert cfg.global_batch % n_shards == 0
    local = cfg.global_batch // n_shards
    return rows_batch(cfg, step, shard * local, (shard + 1) * local)


def make_global_batch(cfg: DataConfig, step: int, batch_sharding):
    """Globally-sharded batch via jax.make_array_from_callback — each
    process touches only its addressable rows."""
    def cb_factory(name):
        def cb(index):
            rows = index[0]
            start = rows.start or 0
            stop = cfg.global_batch if rows.stop is None else rows.stop
            data = rows_batch(cfg, step, start, stop)[name]
            rest = tuple(index[1:])
            return data[(slice(None),) + rest] if rest else data
        return cb

    specs = {}
    if cfg.frontend_dim:
        specs["embeds"] = ((cfg.global_batch, cfg.seq_len,
                            cfg.frontend_dim), jnp.float32)
    else:
        specs["tokens"] = ((cfg.global_batch, cfg.seq_len), jnp.int32)
    specs["labels"] = ((cfg.global_batch, cfg.seq_len), jnp.int32)

    return {
        name: jax.make_array_from_callback(
            shape, batch_sharding, cb_factory(name))
        for name, (shape, dtype) in specs.items()}
